//! Configuration enumeration and simulation-backed scoring.
//!
//! Scoring runs on **warm sessions**: every explorer (serial, pooled,
//! successive-halving) drives a [`crate::sim::batch::Session`] that is
//! re-armed per candidate instead of rebuilding a hierarchy, and the
//! warm-vs-cold equivalence of the re-arm paths keeps all results
//! bitwise-identical to the original cold-build explorer.

use super::bound::{joint_prescreen, prescreen, PruneStats, PrunedPoint};
use super::dims::{Dim, JointSpace, Mapping};
use super::pareto::pareto_front;
use crate::config::{HierarchyConfig, Protection};
use crate::cost::{hierarchy_area, run_power};
use crate::mem::{BudgetedRun, FunctionalModel, Hierarchy, HierarchyCheckpoint};
use crate::pattern::PatternProgram;
use crate::sim::batch::Session;
use crate::sim::SimStats;
use crate::Result;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// A level-kind choice the enumeration can assign to one level position.
/// (Standard port/bank variants stay controlled by
/// [`SearchSpace::try_dual_ported`]; a double-buffered level has no
/// port/bank sub-choices.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KindChoice {
    /// Standard banked level.
    Standard,
    /// Double-buffered (ping-pong) level.
    DoubleBuffered,
}

/// The search space (§4.1 parameters the DSE sweeps, plus the per-level
/// kind dimension).
#[derive(Debug, Clone)]
pub struct SearchSpace {
    /// Candidate hierarchy depths (1..=5).
    pub depths: Vec<usize>,
    /// Candidate RAM depths per level.
    pub ram_depths: Vec<u64>,
    /// Candidate word widths (bits).
    pub word_widths: Vec<u32>,
    /// Level kinds enumerated per level position (every combination is
    /// tried, level 0 most significant in the emission order).
    pub level_kinds: Vec<KindChoice>,
    /// Try dual-ported last levels.
    pub try_dual_ported: bool,
    /// Storage-protection schemes to enumerate (applied uniformly to all
    /// levels of a candidate — the fastest odometer digit). Protection
    /// never changes cycle behavior (see [`crate::config::Protection`]),
    /// only area/energy, so the default single-entry menu keeps every
    /// existing sweep bit-identical.
    pub protections: Vec<Protection>,
    /// Evaluation clock (Hz) for power scoring.
    pub eval_hz: f64,
}

impl Default for SearchSpace {
    fn default() -> Self {
        Self {
            depths: vec![1, 2],
            ram_depths: vec![32, 128, 512, 1024],
            word_widths: vec![32, 128],
            level_kinds: vec![KindChoice::Standard, KindChoice::DoubleBuffered],
            try_dual_ported: true,
            protections: vec![Protection::None],
            eval_hz: 100e6,
        }
    }
}

impl SearchSpace {
    /// A space restricted to standard levels (the pre-kind behavior).
    pub fn standard_only(mut self) -> Self {
        self.level_kinds = vec![KindChoice::Standard];
        self
    }

    /// Lazily enumerate the space's candidate configurations (see
    /// [`Candidates`]): million-candidate spaces stream through a
    /// constant-size odometer instead of materializing a `Vec`.
    pub fn candidates(&self) -> Candidates {
        Candidates::from_dims(&self.dims())
    }
}

/// One evaluated configuration.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    /// The configuration.
    pub config: HierarchyConfig,
    /// Chip area (µm²).
    pub area: f64,
    /// Average power on the workload (W).
    pub power: f64,
    /// Internal cycles to complete the workload.
    pub cycles: u64,
    /// Outputs per cycle.
    pub efficiency: f64,
    /// Whether this point is on the Pareto front (set by [`explore`]).
    pub on_front: bool,
    /// Internal cycles the engine fast-forwarded through while scoring
    /// this point (event-horizon skips; diagnostics, not an objective —
    /// simulated results are identical with skipping disabled).
    pub skipped_cycles: u64,
    /// Fast-forward jumps taken while scoring this point.
    pub ff_jumps: u64,
    /// Unique off-chip words fetched during the run — the joint sweep's
    /// fourth Pareto axis (exact; diagnostics only on config sweeps).
    pub offchip_reads: u64,
    /// The loop-nest mapping this point was scored under (`None` on
    /// config-only sweeps).
    pub mapping: Option<Mapping>,
}

/// Eagerly enumerate candidate configurations (collects the streaming
/// iterator; kept for the seeded-space paths where the whole list is
/// needed anyway).
pub(crate) fn enumerate(space: &SearchSpace) -> Vec<HierarchyConfig> {
    space.candidates().collect()
}

/// Lazy streaming enumeration of a config dimension list — an
/// explicit-state odometer over (word width, level count, depth stack,
/// kind stack, last-level ports), so million-candidate spaces are walked
/// in constant memory instead of being materialized into a `Vec`.
///
/// The odometer owns its menus (extracted from a [`Dim`] list by
/// [`Candidates::from_dims`], the general entry point the joint search
/// re-enumerates config sub-spaces through), so it is a self-contained
/// resumable cursor rather than a borrow of one `SearchSpace`.
///
/// The emission order is lexicographic — word width, depth count, depth
/// stack (monotonically shrinking toward the output), kind stack,
/// last-level ports — with level 0 most significant, identical to the
/// recursive enumeration this replaces (a differential test pins that),
/// which [`super::pool::HierarchyPool`] relies on for deterministic
/// merges. Invalid combinations (e.g. an odd ping-pong depth) fail
/// `build()` and are skipped, as always.
pub struct Candidates {
    /// Word-width menu (slowest dimension).
    word_widths: Vec<u32>,
    /// Level-count menu.
    depths: Vec<usize>,
    /// RAM-depth menu (per level position).
    ram_depths: Vec<u64>,
    /// Level-kind menu (per level position).
    level_kinds: Vec<KindChoice>,
    /// Whether dual-ported last-level variants are enumerated.
    try_dual_ported: bool,
    /// Protection menu (applied uniformly to all levels).
    protections: Vec<Protection>,
    /// Index into `word_widths` (slowest digit).
    w_idx: usize,
    /// Index into `depths`.
    nl_idx: usize,
    /// Per-level indices into `ram_depths`, constrained so the selected
    /// depths never grow toward the output.
    depth_digits: Vec<usize>,
    /// Per-level indices into `level_kinds` (plain mixed-radix, last
    /// level fastest).
    kind_digits: Vec<usize>,
    /// Index into the current port menu.
    port_idx: usize,
    /// Index into `protections` (fastest digit).
    prot_idx: usize,
    done: bool,
}

/// Advance a plain mixed-radix odometer (last digit fastest). Returns
/// `false` on wrap-around (all digits reset to zero).
fn advance_plain(digits: &mut [usize], radix: usize) -> bool {
    for d in digits.iter_mut().rev() {
        *d += 1;
        if *d < radix {
            return true;
        }
        *d = 0;
    }
    false
}

/// Advance a mixed-radix odometer whose selected *values* must stay
/// monotonically non-increasing left to right (the depth-stack rule).
/// Increments the last digit, then repairs any monotonicity violation by
/// advancing the offending digit (with carry) and rescanning — the menu
/// need not be sorted or duplicate-free; the visit order is exactly the
/// recursive descend-with-filter order. Returns `false` on exhaustion.
fn advance_monotone(digits: &mut [usize], menu: &[u64]) -> bool {
    let n = digits.len();
    let mut j = n;
    loop {
        if j == 0 {
            return false;
        }
        j -= 1;
        digits[j] += 1;
        if digits[j] < menu.len() {
            break;
        }
        digits[j] = 0;
    }
    digits[j + 1..].fill(0);
    let mut i = j.max(1);
    while i < n {
        if menu[digits[i]] <= menu[digits[i - 1]] {
            i += 1;
            continue;
        }
        let mut k = i;
        loop {
            digits[k] += 1;
            if digits[k] < menu.len() {
                break;
            }
            digits[k] = 0;
            if k == 0 {
                return false;
            }
            k -= 1;
        }
        digits[k + 1..].fill(0);
        i = k.max(1);
    }
    true
}

impl Candidates {
    /// Build the odometer from a dimension list: config dimensions are
    /// extracted by variant ([`Dim::Mapping`] entries are ignored — the
    /// mapping digit lives in [`super::dims::JointCandidates`]); a
    /// missing dimension leaves its menu empty, which exhausts the
    /// iterator immediately, matching an empty-menu `SearchSpace`.
    pub fn from_dims(dims: &[Dim]) -> Self {
        let mut word_widths = Vec::new();
        let mut depths = Vec::new();
        let mut ram_depths = Vec::new();
        let mut level_kinds = Vec::new();
        let mut try_dual_ported = false;
        // An absent protection dimension means "unprotected", not "empty
        // menu" — dimension lists predating the protection knob must keep
        // enumerating exactly as before.
        let mut protections: Option<Vec<Protection>> = None;
        for d in dims {
            match d {
                Dim::Mapping(_) => {}
                Dim::WordWidth(v) => word_widths = v.clone(),
                Dim::LevelCount(v) => depths = v.clone(),
                Dim::DepthStack(v) => ram_depths = v.clone(),
                Dim::LevelKinds(v) => level_kinds = v.clone(),
                Dim::LastLevelPorts(b) => try_dual_ported = *b,
                Dim::Protection(v) => protections = Some(v.clone()),
            }
        }
        let protections = protections.unwrap_or_else(|| vec![Protection::None]);
        let done = word_widths.is_empty() || depths.is_empty() || protections.is_empty();
        let mut it = Self {
            word_widths,
            depths,
            ram_depths,
            level_kinds,
            try_dual_ported,
            protections,
            w_idx: 0,
            nl_idx: 0,
            depth_digits: Vec::new(),
            kind_digits: Vec::new(),
            port_idx: 0,
            prot_idx: 0,
            done,
        };
        if !it.done && !it.enter_shape() {
            it.advance_shape();
        }
        it
    }

    /// Initialize the digits for the current (word width, level count)
    /// shape; `false` if the shape can emit nothing (empty menus).
    fn enter_shape(&mut self) -> bool {
        let nl = self.depths[self.nl_idx];
        if nl > 0 && (self.ram_depths.is_empty() || self.level_kinds.is_empty()) {
            return false;
        }
        self.depth_digits = vec![0; nl];
        self.kind_digits = vec![0; nl];
        self.port_idx = 0;
        self.prot_idx = 0;
        true
    }

    /// Move to the next non-empty (word width, level count) shape, or
    /// mark the iterator exhausted.
    fn advance_shape(&mut self) {
        loop {
            self.nl_idx += 1;
            if self.nl_idx == self.depths.len() {
                self.nl_idx = 0;
                self.w_idx += 1;
                if self.w_idx == self.word_widths.len() {
                    self.done = true;
                    return;
                }
            }
            if self.enter_shape() {
                return;
            }
        }
    }

    /// Port menu of the current kind stack: dual-port variants exist only
    /// for a standard last level.
    fn port_menu(&self) -> &'static [u32] {
        let last_standard = self
            .kind_digits
            .last()
            .map(|&k| matches!(self.level_kinds[k], KindChoice::Standard))
            .unwrap_or(false);
        if last_standard && self.try_dual_ported {
            &[1, 2]
        } else {
            &[1]
        }
    }

    /// Build the configuration at the current odometer position (`None`
    /// if the builder rejects the combination).
    fn build_current(&self) -> Option<HierarchyConfig> {
        let w = self.word_widths[self.w_idx];
        let last_ports = self.port_menu()[self.port_idx];
        let prot = self.protections[self.prot_idx];
        let nl = self.depth_digits.len();
        let mut b = HierarchyConfig::builder().offchip(32, 24, 1.0);
        for i in 0..nl {
            let d = self.ram_depths[self.depth_digits[i]];
            b = match self.level_kinds[self.kind_digits[i]] {
                KindChoice::Standard => {
                    let ports = if i + 1 == nl { last_ports } else { 1 };
                    b.level(w, d, 1, ports)
                }
                KindChoice::DoubleBuffered => b.level_double_buffered(w, d),
            };
            b = b.protect(prot);
        }
        if w > 32 {
            b = b.osr(w.max(64), vec![32]);
        }
        b.build().ok()
    }

    /// Step the odometer once (protection fastest, then ports, then
    /// kinds, then depths, then the shape).
    fn advance(&mut self) {
        self.prot_idx += 1;
        if self.prot_idx < self.protections.len() {
            return;
        }
        self.prot_idx = 0;
        self.port_idx += 1;
        if self.port_idx < self.port_menu().len() {
            return;
        }
        self.port_idx = 0;
        if advance_plain(&mut self.kind_digits, self.level_kinds.len()) {
            return;
        }
        if advance_monotone(&mut self.depth_digits, &self.ram_depths) {
            return;
        }
        self.advance_shape();
    }
}

impl Iterator for Candidates {
    type Item = HierarchyConfig;

    fn next(&mut self) -> Option<HierarchyConfig> {
        while !self.done {
            let cfg = self.build_current();
            self.advance();
            if cfg.is_some() {
                return cfg;
            }
        }
        None
    }
}

/// Aggregate fast-forward accounting over a sweep's scored points:
/// summed `(skipped_cycles, simulated_cycles, ff_jumps)` — the totals
/// `dse_sweep` and the CLI `dse` summary print next to a sweep.
pub fn ff_totals(points: &[DesignPoint]) -> (u64, u64, u64) {
    points.iter().fold((0, 0, 0), |(s, c, j), p| {
        (s + p.skipped_cycles, c + p.cycles, j + p.ff_jumps)
    })
}

/// Turn a completed run into a scored design point.
pub(crate) fn score(config: HierarchyConfig, stats: &SimStats, eval_hz: f64) -> DesignPoint {
    let area = hierarchy_area(&config).total;
    let power = run_power(&config, stats, eval_hz).total;
    DesignPoint {
        config,
        area,
        power,
        cycles: stats.internal_cycles,
        efficiency: stats.efficiency(),
        on_front: false,
        skipped_cycles: stats.skipped_cycles,
        ff_jumps: stats.ff_jumps,
        offchip_reads: stats.offchip_reads,
        mapping: None,
    }
}

/// Per-worker evaluation state: one warm [`Session`] re-armed for every
/// candidate it scores, created lazily on the first valid config. The
/// warm-vs-cold determinism of the re-arm paths makes the session history
/// invisible in the results.
///
/// Scoring never verifies payloads (a pure performance measurement), and
/// the choice is owned by the *session* — set once at creation and
/// re-asserted by every re-arm — instead of being poked onto the
/// hierarchy per run, so it cannot leak into (or out of) other users of a
/// warm session.
pub(crate) struct EvalSession {
    session: Option<Session>,
}

impl EvalSession {
    /// A fresh (cold) evaluation session.
    pub(crate) fn new() -> Self {
        Self { session: None }
    }

    /// The warm hierarchy re-armed for `cfg`, or `None` if the config is
    /// invalid (the candidate is skipped, as always).
    pub(crate) fn hierarchy_for(&mut self, cfg: &HierarchyConfig) -> Option<&mut Hierarchy> {
        match self.session.take() {
            Some(mut s) => {
                // `rearm` validates before mutating, so a rejected config
                // leaves the session intact — keep its warmth for the
                // next candidate instead of paying a cold rebuild.
                let ok = s.rearm(cfg).is_ok();
                self.session = Some(s);
                if !ok {
                    return None;
                }
            }
            None => {
                let mut s = Session::new(cfg).ok()?;
                s.set_verify(false);
                self.session = Some(s);
            }
        }
        self.session.as_mut().map(Session::hierarchy)
    }

    /// Run the workload on `cfg` and return the raw statistics (`None`
    /// on the usual skip conditions). The memoized joint explorer scores
    /// a whole behavioral class from one representative's stats, so the
    /// run and the scoring are separable here.
    pub(crate) fn run_stats(
        &mut self,
        cfg: &HierarchyConfig,
        workload: &PatternProgram,
    ) -> Option<SimStats> {
        let h = self.hierarchy_for(cfg)?;
        if h.load_program(workload).is_err() {
            return None;
        }
        Some(h.run().ok()?.stats)
    }

    /// Score one candidate against the workload by simulation. Returns
    /// `None` for configs the program does not align with (packing) or
    /// that fail to simulate — the same skip semantics the cold explorer
    /// always had.
    pub(crate) fn evaluate(
        &mut self,
        cfg: HierarchyConfig,
        workload: &PatternProgram,
        eval_hz: f64,
    ) -> Option<DesignPoint> {
        let stats = self.run_stats(&cfg, workload)?;
        Some(score(cfg, &stats, eval_hz))
    }
}

/// Cold-build scoring of one candidate (a fresh hierarchy per call): the
/// reference the warm paths are tested against.
#[cfg(test)]
pub(crate) fn evaluate(
    cfg: HierarchyConfig,
    workload: &PatternProgram,
    eval_hz: f64,
) -> Option<DesignPoint> {
    EvalSession::new().evaluate(cfg, workload, eval_hz)
}

/// Mark the Pareto front and sort by area. Shared tail of the serial and
/// pooled explorers: given the same points in the same order it produces
/// bit-for-bit identical results, so determinism reduces to feeding it
/// the evaluation results in enumeration order. With `traffic` set the
/// front is taken over four axes — (area, power, cycles, off-chip
/// reads) — the joint sweep's objective space; config-only sweeps keep
/// the original three.
pub(crate) fn finalize_axes(mut points: Vec<DesignPoint>, traffic: bool) -> Vec<DesignPoint> {
    let objs: Vec<Vec<f64>> = points
        .iter()
        .map(|p| {
            let mut o = vec![p.area, p.power, p.cycles as f64];
            if traffic {
                o.push(p.offchip_reads as f64);
            }
            o
        })
        .collect();
    for i in pareto_front(&objs) {
        points[i].on_front = true;
    }
    points.sort_by(|a, b| a.area.total_cmp(&b.area));
    points
}

/// [`finalize_axes`] over the classic three objectives.
pub(crate) fn finalize(points: Vec<DesignPoint>) -> Vec<DesignPoint> {
    finalize_axes(points, false)
}

/// Explore the space against a workload pattern; returns all evaluated
/// points with the Pareto front marked, sorted by area.
///
/// This is the serial reference path, scored on one warm session
/// (re-armed per candidate, never reallocated);
/// [`super::pool::HierarchyPool`] produces bitwise-identical results on
/// multiple threads, and both are bitwise-identical to cold-build
/// scoring.
pub fn explore(space: &SearchSpace, workload: &PatternProgram) -> Result<Vec<DesignPoint>> {
    let mut session = EvalSession::new();
    let points = enumerate(space)
        .into_iter()
        .filter_map(|cfg| session.evaluate(cfg, workload, space.eval_hz))
        .collect();
    Ok(finalize(points))
}

/// Result of [`explore_pruned`]: the exactly-scored survivors (finalized
/// like [`explore`]), the analytically pruned candidates (bound-scored,
/// never simulated), and the prune accounting.
#[derive(Debug, Clone)]
pub struct PrunedExplore {
    /// Exactly-scored design points (the prescreen survivors), Pareto
    /// front marked, sorted by area. The marked front is bitwise
    /// identical to the exhaustive [`explore`] front (the prunes are
    /// provably off it).
    pub points: Vec<DesignPoint>,
    /// Candidates the prescreen dropped, in enumeration order.
    pub pruned: Vec<PrunedPoint>,
    /// Work accounting.
    pub stats: PruneStats,
}

/// [`explore`] behind the analytical bound-and-prune front end
/// ([`crate::dse::bound`]): candidates stream from the enumeration
/// through the prescreen, and only survivors are simulated. The marked
/// Pareto front is bitwise identical to the exhaustive sweep's; pruned
/// candidates come back bound-scored in [`PrunedExplore::pruned`].
pub fn explore_pruned(
    space: &SearchSpace,
    workload: &PatternProgram,
) -> Result<PrunedExplore> {
    let outcome = prescreen(space, workload);
    let mut stats = outcome.stats;
    let mut session = EvalSession::new();
    let points: Vec<DesignPoint> = outcome
        .survivors
        .into_iter()
        .filter_map(|cfg| session.evaluate(cfg, workload, space.eval_hz))
        .collect();
    // Survivors the simulator still skips (misalignment beyond compile
    // failures) move from the simulated column to the skipped one.
    stats.skipped += stats.simulated - points.len();
    stats.simulated = points.len();
    Ok(PrunedExplore { points: finalize(points), pruned: outcome.pruned, stats })
}

/// Successive-halving schedule: ascending screening budgets in internal
/// cycles. Screening is **incremental**: every undecided candidate
/// carries a [`HierarchyCheckpoint`] across rungs, so rung *k* resumes
/// the candidate from its rung *k−1* state and simulates only the budget
/// **delta** — the screened prefix is never re-paid. Candidates that
/// complete within a budget are thereby **exactly** scored (a resumed
/// budgeted run that finishes is bit-identical to an uninterrupted full
/// run), and between rungs candidates whose screened metrics are dominated
/// are dropped. Survivors are *resumed to completion* (not restarted), so
/// every returned point carries its exact score while the sweep pays each
/// simulated cycle exactly once. [`HalvingStats`] reports the inherited
/// work (`saved_cycles`) and the resumed deltas (`resumed_cycles`);
/// [`explore_halving_restart`] keeps the re-run-from-scratch strategy
/// available as the benchmark baseline.
///
/// Pruning compares screened proxies (exact area, emitted units at equal
/// budget, average power over the screened window). On workloads whose
/// steady-state rate is reached within the first budget — every §3.2
/// pattern family qualifies — the screened ordering matches the final
/// ordering and the resulting Pareto front is identical to the exhaustive
/// one; the `warm_session` and `checkpoint` tests assert bitwise equality
/// on seeded spaces. An empty budget list degenerates to the exhaustive
/// sweep.
#[derive(Debug, Clone)]
pub struct HalvingSchedule {
    /// Screening cycle budgets, ascending.
    pub budgets: Vec<u64>,
}

impl HalvingSchedule {
    /// A two-rung schedule proportional to the workload: a short screen
    /// at half the output count (past the fill knee of every pattern
    /// family) and a long screen just above it, so full-rate candidates
    /// complete — and are exactly scored — during screening.
    pub fn for_workload(workload: &PatternProgram) -> Self {
        let u = workload.total_outputs;
        Self { budgets: vec![u / 2 + 256, 2 * u + 512] }
    }

    /// [`Self::for_workload`] sized by the largest workload of a joint
    /// sweep, so every mapping's candidates get past their fill knee.
    pub fn for_workloads(workloads: &[PatternProgram]) -> Self {
        let u = workloads.iter().map(|w| w.total_outputs).max().unwrap_or(0);
        Self { budgets: vec![u / 2 + 256, 2 * u + 512] }
    }
}

/// Work accounting of a successive-halving sweep, including cycle-level
/// resume accounting (all cycle counts are internal cycles).
///
/// ## Equality
///
/// `PartialEq` compares the **sweep semantics** only: the scheduling
/// diagnostics (`worker_items`, `steals`) depend on the worker count and
/// on runtime load balance, so they are excluded — a serial, a pooled,
/// and a sharded sweep of the same space compare equal, which is exactly
/// the determinism the differential tests assert.
#[derive(Debug, Clone, Default, Eq)]
pub struct HalvingStats {
    /// Candidates enumerated.
    pub candidates: usize,
    /// Candidates whose screening run completed (exactly scored without a
    /// separate completion run).
    pub screen_exact: usize,
    /// Candidates dropped between rungs as screened-dominated.
    pub pruned: usize,
    /// Survivors that needed a dedicated completion run (resumed from
    /// their last screening checkpoint, or run in full in restart mode).
    pub full_runs: usize,
    /// Candidates the workload does not align with or that failed to
    /// simulate.
    pub skipped: usize,
    /// Cycles actually simulated by runs that continued from a checkpoint
    /// (the budget deltas executed on top of inherited state).
    pub resumed_cycles: u64,
    /// Cycles inherited from checkpoints instead of being re-simulated —
    /// exactly the screened prefixes the restart strategy
    /// ([`explore_halving_restart`]) pays again at every rung and once
    /// more in each survivor's full run. Zero in restart mode.
    pub saved_cycles: u64,
    /// Candidates dropped by the analytical prescreen before any rung ran
    /// (never simulated; see [`crate::dse::bound`]). Zero without
    /// pruning.
    pub bound_pruned: usize,
    /// Lower bound on the simulated cycles the analytical prunes avoided
    /// (sum of the pruned candidates' cycle lower bounds). Zero without
    /// pruning.
    pub bound_cycles_saved: u64,
    /// Peak bytes of suspended-candidate blobs the shard coordinator held
    /// at any instant (zero for in-process sweeps). Memory diagnostics —
    /// excluded from `PartialEq`.
    pub blob_bytes_peak: u64,
    /// Total bytes of suspended-candidate blobs the shard coordinator
    /// ever stored (zero for in-process sweeps). Memory diagnostics —
    /// excluded from `PartialEq`.
    pub blob_bytes_inserted: u64,
    /// Candidates evaluated per worker (utilization; index = worker).
    /// Scheduling diagnostics — excluded from `PartialEq`.
    pub worker_items: Vec<u64>,
    /// Evaluations a worker claimed from the shared queue whose static
    /// owner (`index % workers`) was a different worker — the work the
    /// stealing queue moved to keep workers busy. Zero when serial.
    /// Scheduling diagnostics — excluded from `PartialEq`.
    pub steals: u64,
    /// Worker processes the shard coordinator respawned after a crash,
    /// hang, or corrupt frame (zero for in-process sweeps). Resilience
    /// diagnostics — excluded from `PartialEq`: a sweep that lost and
    /// re-dispatched candidates still compares equal to a serial one.
    pub respawns: u64,
    /// Exponential-backoff waits taken before respawning a repeatedly
    /// failing worker slot (zero for in-process sweeps). Resilience
    /// diagnostics — excluded from `PartialEq`.
    pub backoffs: u64,
}

impl PartialEq for HalvingStats {
    /// Sweep-semantics equality (see the type docs): every counter except
    /// the scheduling diagnostics. Destructured so a newly added counter
    /// must be classified here explicitly.
    fn eq(&self, other: &Self) -> bool {
        let Self {
            candidates,
            screen_exact,
            pruned,
            full_runs,
            skipped,
            resumed_cycles,
            saved_cycles,
            bound_pruned,
            bound_cycles_saved,
            blob_bytes_peak: _,
            blob_bytes_inserted: _,
            worker_items: _,
            steals: _,
            respawns: _,
            backoffs: _,
        } = self;
        *candidates == other.candidates
            && *screen_exact == other.screen_exact
            && *pruned == other.pruned
            && *full_runs == other.full_runs
            && *skipped == other.skipped
            && *resumed_cycles == other.resumed_cycles
            && *saved_cycles == other.saved_cycles
            && *bound_pruned == other.bound_pruned
            && *bound_cycles_saved == other.bound_cycles_saved
    }
}

/// Result of [`explore_halving`]: the exactly-scored points (finalized
/// like [`explore`]: Pareto front marked, sorted by area) plus the work
/// accounting. Pruned candidates do not appear in `points`; because they
/// are dominated, the marked front is the same as the exhaustive one on
/// rate-faithful workloads (see [`HalvingSchedule`]).
#[derive(Debug, Clone)]
pub struct HalvingOutcome {
    /// Exactly-scored design points.
    pub points: Vec<DesignPoint>,
    /// Candidates the analytical prescreen dropped (bound-scored, in
    /// enumeration order; empty without pruning). Provably off the exact
    /// front — returned flagged, never silently vanished.
    pub pruned: Vec<PrunedPoint>,
    /// Work accounting.
    pub stats: HalvingStats,
}

/// Screened proxy metrics of one candidate at the latest rung.
#[derive(Debug, Clone, Copy)]
pub(crate) struct Screen {
    /// Off-chip units emitted within the budget (higher = faster).
    pub(crate) units: u64,
    /// Exact chip area.
    pub(crate) area: f64,
    /// Average power over the screened window.
    pub(crate) power: f64,
    /// Exact analytic off-chip reads of the candidate's full run — the
    /// joint sweep's traffic axis. Always 0 on config-only sweeps (the
    /// axis is disabled and cancels out of every comparison), filled by
    /// the halving driver when the traffic axis is on.
    pub(crate) traffic: u64,
}

/// Screened dominance (lower area/power/traffic better, higher units
/// better, at least one strictly).
pub(crate) fn screen_dominates(q: &Screen, p: &Screen) -> bool {
    q.area <= p.area
        && q.units >= p.units
        && q.power <= p.power
        && q.traffic <= p.traffic
        && (q.area < p.area || q.units > p.units || q.power < p.power || q.traffic < p.traffic)
}

/// One candidate's screening run on a warm session.
pub(crate) enum ScreenOutcome {
    /// Config invalid / misaligned / failed to simulate.
    Skip,
    /// Completed within the budget: exactly scored.
    Exact(DesignPoint),
    /// Budget expired: proxy metrics only.
    Partial(Screen),
}

/// Result of one budgeted candidate evaluation ([`eval_budgeted`]): the
/// screening outcome, the updated suspended state (when requested and
/// still suspended), and the cycle accounting deltas.
pub(crate) struct EvalDelta {
    /// The screening outcome.
    pub(crate) outcome: ScreenOutcome,
    /// Updated checkpoint for a still-suspended candidate (`None` when
    /// the candidate was decided, failed, or `keep_ckpt` was off).
    pub(crate) ckpt: Option<HierarchyCheckpoint>,
    /// Cycles simulated on top of inherited state (0 without a restore).
    pub(crate) resumed: u64,
    /// Cycles inherited from the checkpoint instead of re-simulated.
    pub(crate) saved: u64,
}

/// Evaluate one candidate up to the absolute cycle budget `budget`
/// (`u64::MAX` = run to completion), resuming from `inherited` when
/// given. This is **the** candidate evaluation used by every halving
/// path — serial, pooled, and the sharded worker process
/// ([`crate::dse::shard`]) — so their per-candidate results are
/// bit-identical by construction.
///
/// A restore failure falls back to a from-scratch run (same silent
/// fallback the checkpoint layer always had); when `keep_ckpt` is set a
/// still-suspended candidate's updated state is returned in
/// [`EvalDelta::ckpt`]. A `Partial` under budget `u64::MAX` means the
/// run cannot complete (deadlock guard) and is reported as `Skip`.
pub(crate) fn eval_budgeted(
    sess: &mut EvalSession,
    cfg: &HierarchyConfig,
    workload: &PatternProgram,
    budget: u64,
    eval_hz: f64,
    inherited: Option<&HierarchyCheckpoint>,
    keep_ckpt: bool,
) -> EvalDelta {
    let skip = |outcome| EvalDelta { outcome, ckpt: None, resumed: 0, saved: 0 };
    let Some(h) = sess.hierarchy_for(cfg) else {
        return skip(ScreenOutcome::Skip);
    };
    if h.load_program(workload).is_err() {
        return skip(ScreenOutcome::Skip);
    }
    let mut inherited_cycles = 0u64;
    if let Some(ck) = inherited {
        if h.restore(ck).is_ok() {
            inherited_cycles = ck.cycles();
        }
    }
    let account = |cycles: u64| {
        if inherited_cycles > 0 {
            (cycles - inherited_cycles, inherited_cycles)
        } else {
            (0, 0)
        }
    };
    match h.run_budgeted(budget.saturating_sub(inherited_cycles)) {
        Err(_) => skip(ScreenOutcome::Skip),
        Ok(BudgetedRun::Complete(r)) => {
            let (resumed, saved) = account(r.stats.internal_cycles);
            EvalDelta {
                outcome: ScreenOutcome::Exact(score(cfg.clone(), &r.stats, eval_hz)),
                ckpt: None,
                resumed,
                saved,
            }
        }
        Ok(BudgetedRun::Partial { cycles, units_out }) => {
            if budget == u64::MAX {
                // A completion run that still suspended: the deadlock
                // guard fired. Same skip semantics as a failed run.
                return skip(ScreenOutcome::Skip);
            }
            let (resumed, saved) = account(cycles);
            let snap = h.stats_snapshot();
            let screen = Screen {
                units: units_out,
                area: hierarchy_area(cfg).total,
                power: run_power(cfg, &snap, eval_hz).total,
                traffic: 0,
            };
            let ckpt = if keep_ckpt { h.snapshot().ok() } else { None };
            EvalDelta { outcome: ScreenOutcome::Partial(screen), ckpt, resumed, saved }
        }
    }
}

/// Shared suspended-candidate store, keyed by candidate index. One store
/// serves all workers of a sweep: the work-stealing queue means any
/// worker may resume any candidate, so checkpoints live behind a mutex
/// instead of per-worker maps. Accesses are one `take` and at most one
/// `put` per candidate evaluation — negligible next to the simulation
/// they bracket.
///
/// Peak memory during screening is one [`HierarchyCheckpoint`] per
/// still-undecided candidate ([`CkptStore::retain`] trims decided and
/// pruned candidates after every rung) — the price of never re-paying
/// screened cycles. Restart mode ([`explore_halving_restart`]) keeps no
/// checkpoints and peaks at one warm hierarchy per worker.
struct CkptStore {
    ckpts: Mutex<BTreeMap<usize, HierarchyCheckpoint>>,
}

impl CkptStore {
    fn new() -> Self {
        Self { ckpts: Mutex::new(BTreeMap::new()) }
    }

    /// Remove and return candidate `idx`'s suspended state.
    fn take(&self, idx: usize) -> Option<HierarchyCheckpoint> {
        self.ckpts.lock().expect("worker panicked holding checkpoint store").remove(&idx)
    }

    /// Store candidate `idx`'s suspended state for the next rung.
    fn put(&self, idx: usize, ck: HierarchyCheckpoint) {
        self.ckpts.lock().expect("worker panicked holding checkpoint store").insert(idx, ck);
    }

    /// Drop every checkpoint whose candidate index fails `keep`.
    fn retain(&self, keep: impl Fn(usize) -> bool) {
        let mut ckpts = self.ckpts.lock().expect("worker panicked holding checkpoint store");
        ckpts.retain(|i, _| keep(*i));
    }
}

/// One halving worker: a warm evaluation session plus a handle on the
/// sweep-shared checkpoint store and its utilization counters.
struct HalvingWorker<'s> {
    sess: EvalSession,
    /// Suspended candidate states, shared by all workers of the sweep.
    store: &'s CkptStore,
    /// Cycles simulated by runs resumed from a checkpoint (deltas only).
    resumed_cycles: u64,
    /// Cycles inherited from checkpoints instead of re-simulated.
    saved_cycles: u64,
    /// Candidates this worker evaluated (→ [`HalvingStats::worker_items`]).
    items: u64,
    /// Evaluations claimed whose static owner was a different worker
    /// (→ [`HalvingStats::steals`]).
    steals: u64,
}

impl<'s> HalvingWorker<'s> {
    fn new(store: &'s CkptStore) -> Self {
        Self {
            sess: EvalSession::new(),
            store,
            resumed_cycles: 0,
            saved_cycles: 0,
            items: 0,
            steals: 0,
        }
    }
}

/// Run `f` over `items` (candidate indices) on the per-worker states,
/// with workers claiming candidates from a shared atomic cursor — the
/// same work-stealing queue shape as
/// [`crate::util::par_map_indexed_with`] (which cannot be reused directly
/// because the worker state is owned externally and must survive across
/// passes) and as the shard coordinator's dispatch loop
/// ([`crate::dse::shard`]). Results come back sorted by candidate index,
/// so the merged order — and with it every downstream decision — is
/// independent of thread count and scheduling (each candidate's outcome
/// is already deterministic thanks to the warm==cold re-arm guarantee and
/// the determinism of restore, and any worker can resume any candidate
/// through the shared [`CkptStore`]).
///
/// Claims off the cursor are tallied per worker: a claim whose static
/// owner (`index-in-pass % workers`) is a different worker counts as a
/// steal — the imbalance a static assignment would have stranded.
fn run_pass<R, F>(workers: &mut [HalvingWorker<'_>], items: &[usize], f: F) -> Vec<(usize, R)>
where
    R: Send,
    F: Fn(&mut HalvingWorker<'_>, usize) -> R + Sync,
{
    let t = workers.len();
    if t == 1 {
        let worker = &mut workers[0];
        worker.items += items.len() as u64;
        return items.iter().map(|&i| (i, f(worker, i))).collect();
    }
    let cursor = AtomicUsize::new(0);
    let results = Mutex::new(Vec::with_capacity(items.len()));
    std::thread::scope(|scope| {
        for (w, worker) in workers.iter_mut().enumerate() {
            let (cursor, results, f) = (&cursor, &results, &f);
            scope.spawn(move || {
                let mut local = Vec::new();
                loop {
                    let k = cursor.fetch_add(1, Ordering::Relaxed);
                    let Some(&i) = items.get(k) else { break };
                    worker.items += 1;
                    if k % t != w {
                        worker.steals += 1;
                    }
                    local.push((i, f(&mut *worker, i)));
                }
                results.lock().expect("worker panicked holding lock").extend(local);
            });
        }
    });
    let mut merged = results.into_inner().expect("worker panicked holding lock");
    merged.sort_by_key(|&(i, _)| i);
    merged
}

/// Screen one candidate up to the absolute cycle budget `budget`,
/// resuming from the shared store's checkpoint when `resume` is set
/// (then only the budget delta is simulated). A still-suspended candidate
/// leaves an updated checkpoint behind for the next rung.
fn screen_candidate(
    w: &mut HalvingWorker<'_>,
    idx: usize,
    cfg: &HierarchyConfig,
    workload: &PatternProgram,
    budget: u64,
    eval_hz: f64,
    resume: bool,
) -> ScreenOutcome {
    let inherited = if resume { w.store.take(idx) } else { None };
    let delta =
        eval_budgeted(&mut w.sess, cfg, workload, budget, eval_hz, inherited.as_ref(), resume);
    w.resumed_cycles += delta.resumed;
    w.saved_cycles += delta.saved;
    if let Some(ck) = delta.ckpt {
        w.store.put(idx, ck);
    }
    delta.outcome
}

/// Finish one surviving candidate exactly: resume from its last screening
/// checkpoint (when `resume` is set) and run to completion, instead of
/// restarting from cycle zero.
fn finish_candidate(
    w: &mut HalvingWorker<'_>,
    idx: usize,
    cfg: &HierarchyConfig,
    workload: &PatternProgram,
    eval_hz: f64,
    resume: bool,
) -> Option<DesignPoint> {
    let inherited = if resume { w.store.take(idx) } else { None };
    let delta =
        eval_budgeted(&mut w.sess, cfg, workload, u64::MAX, eval_hz, inherited.as_ref(), false);
    w.resumed_cycles += delta.resumed;
    w.saved_cycles += delta.saved;
    match delta.outcome {
        ScreenOutcome::Exact(p) => Some(p),
        ScreenOutcome::Skip | ScreenOutcome::Partial(_) => None,
    }
}

/// Explore with successive halving on one warm session per worker; see
/// [`HalvingSchedule`] for the semantics. Candidates are suspended and
/// resumed across rungs via [`HierarchyCheckpoint`], so the screened
/// prefix is simulated exactly once. `threads = 1` here; the pooled
/// variant is [`super::pool::HierarchyPool::explore_halving`].
pub fn explore_halving(
    space: &SearchSpace,
    workload: &PatternProgram,
    schedule: &HalvingSchedule,
) -> Result<HalvingOutcome> {
    halving_impl(space, workload, schedule, 1, true, false)
}

/// [`explore_halving`] behind the analytical bound-and-prune front end:
/// the prescreen ([`crate::dse::bound`]) drops provably-dominated
/// candidates before rung 0, so the rungs screen only survivors. The
/// marked front stays bitwise identical to the exhaustive one (on
/// rate-faithful workloads, as always); the analytically pruned
/// candidates come back in [`HalvingOutcome::pruned`] and the stats gain
/// `bound_pruned` / `bound_cycles_saved`.
pub fn explore_halving_pruned(
    space: &SearchSpace,
    workload: &PatternProgram,
    schedule: &HalvingSchedule,
) -> Result<HalvingOutcome> {
    halving_impl(space, workload, schedule, 1, true, true)
}

/// [`explore_halving`] with restart screening: every rung re-runs each
/// undecided candidate from scratch and survivors restart their full run
/// (the pre-checkpoint strategy). Produces a bitwise-identical
/// [`HalvingOutcome`] — modulo `resumed_cycles`/`saved_cycles`, which are
/// zero here — at strictly more simulated cycles; kept as the baseline
/// the `halving_resume` bench and the differential tests compare against.
pub fn explore_halving_restart(
    space: &SearchSpace,
    workload: &PatternProgram,
    schedule: &HalvingSchedule,
) -> Result<HalvingOutcome> {
    halving_impl(space, workload, schedule, 1, false, false)
}

/// Per-candidate sweep state, shared by the in-process halving driver
/// ([`halving_impl`]) and the multi-process shard coordinator
/// ([`crate::dse::shard`]) so their decision machinery is one code path.
#[derive(Clone)]
pub(crate) enum CandidateState {
    /// Still screening; carries the latest rung's proxy metrics.
    Undecided(Option<Screen>),
    /// Exactly scored (screen completed, or finished by a survivor run).
    Exact(DesignPoint),
    /// Dropped between rungs as screened-dominated.
    Pruned,
    /// Invalid / misaligned / failed to simulate.
    Skipped,
}

/// Indices still undecided, in enumeration order.
pub(crate) fn undecided_indices(states: &[CandidateState]) -> Vec<usize> {
    states
        .iter()
        .enumerate()
        .filter(|(_, s)| matches!(s, CandidateState::Undecided(_)))
        .map(|(i, _)| i)
        .collect()
}

/// The between-rung prune rule: a still-undecided candidate whose
/// screened metrics are dominated by any other live candidate's is
/// dropped. Exactly scored candidates participate as dominators with
/// their final metrics (they emitted every unit of their workload).
/// Returns the number of candidates pruned. A pure function of the
/// merged screening results — the decisions are identical however (and
/// wherever) the rung was evaluated.
///
/// `widx[i]` names the workload candidate `i` is scored on and
/// `total_outputs[w]` that workload's output count. Dominance is only
/// tested **within a workload group**: units-at-equal-budget across
/// different workloads measure different work, so cross-mapping screened
/// comparisons are unsound and never made (the exact four-axis front
/// still compares every point at [`finalize_axes`] time). Config-only
/// sweeps pass a single group and behave exactly as before. With
/// `traffic_axis` set, exactly-scored dominators carry their off-chip
/// reads; without it every [`Screen::traffic`] is zero and the axis
/// cancels out.
pub(crate) fn prune_dominated(
    states: &mut [CandidateState],
    widx: &[usize],
    total_outputs: &[u64],
    traffic_axis: bool,
) -> usize {
    let live: Vec<(usize, Screen)> = states
        .iter()
        .enumerate()
        .filter_map(|(i, s)| match s {
            CandidateState::Undecided(Some(sc)) => Some((i, *sc)),
            CandidateState::Exact(p) => Some((
                i,
                Screen {
                    units: total_outputs[widx[i]],
                    area: p.area,
                    power: p.power,
                    traffic: if traffic_axis { p.offchip_reads } else { 0 },
                },
            )),
            _ => None,
        })
        .collect();
    let mut pruned = 0;
    for &(i, sc) in &live {
        if !matches!(states[i], CandidateState::Undecided(_)) {
            continue;
        }
        if live.iter().any(|&(j, q)| j != i && widx[j] == widx[i] && screen_dominates(&q, &sc)) {
            states[i] = CandidateState::Pruned;
            pruned += 1;
        }
    }
    pruned
}

/// Shared serial/pooled successive-halving implementation. Results are
/// independent of `threads` *and* of `resume`: the work-stealing pass
/// merges screening results in enumeration order, the prune rule is a
/// pure function of the merged screening results, and a resumed run is
/// bit-identical to its restarted equivalent (the checkpoint layer's
/// guarantee) — only the cycle accounting and the scheduling diagnostics
/// ([`HalvingStats::worker_items`], [`HalvingStats::steals`]) differ.
///
/// With `prune` set, the analytical prescreen ([`crate::dse::bound`])
/// runs over the streaming enumeration first and the rungs only ever see
/// its survivors; `candidates`/`skipped` then count the *full*
/// enumeration (accounting invariant: `screen_exact + pruned + full_runs
/// + skipped + bound_pruned == candidates`).
pub(crate) fn halving_impl(
    space: &SearchSpace,
    workload: &PatternProgram,
    schedule: &HalvingSchedule,
    threads: usize,
    resume: bool,
    prune: bool,
) -> Result<HalvingOutcome> {
    let (candidates, bound_pruned, hstats) = if prune {
        let outcome = prescreen(space, workload);
        let hstats = HalvingStats {
            candidates: outcome.stats.enumerated,
            skipped: outcome.stats.skipped,
            bound_pruned: outcome.stats.bound_pruned,
            bound_cycles_saved: outcome.stats.cycles_saved_lb,
            ..Default::default()
        };
        (outcome.survivors, outcome.pruned, hstats)
    } else {
        let candidates = enumerate(space);
        let hstats = HalvingStats { candidates: candidates.len(), ..Default::default() };
        (candidates, Vec::new(), hstats)
    };
    halving_core(
        candidates.into_iter().map(|c| (0, c)).collect(),
        std::slice::from_ref(workload),
        None,
        schedule,
        threads,
        resume,
        space.eval_hz,
        false,
        bound_pruned,
        hstats,
    )
}

/// The halving engine behind both the config-only and the joint sweeps:
/// candidates are *(workload index, config)* pairs over a workload menu
/// (a single workload for config sweeps; one derived workload per
/// mapping for joint sweeps, with `mappings` re-attached to the scored
/// points). The between-rung prune groups by workload index (see
/// [`prune_dominated`]) and with `traffic_axis` set each suspended
/// candidate's [`Screen`] carries its exact analytic off-chip reads
/// ([`FunctionalModel::expected_offchip_reads`] — budget-independent, so
/// a screened proxy comparison on traffic is already exact) and the
/// final front is taken over four axes.
#[allow(clippy::too_many_arguments)]
pub(crate) fn halving_core(
    candidates: Vec<(usize, HierarchyConfig)>,
    workloads: &[PatternProgram],
    mappings: Option<&[Mapping]>,
    schedule: &HalvingSchedule,
    threads: usize,
    resume: bool,
    eval_hz: f64,
    traffic_axis: bool,
    bound_pruned: Vec<PrunedPoint>,
    mut hstats: HalvingStats,
) -> Result<HalvingOutcome> {
    use CandidateState as State;

    let n = candidates.len();
    let threads = threads.max(1).min(n.max(1));
    let widx: Vec<usize> = candidates.iter().map(|&(w, _)| w).collect();
    let group_outputs: Vec<u64> = workloads.iter().map(|w| w.total_outputs).collect();
    let mut states: Vec<State> = vec![State::Undecided(None); n];
    // Analytic traffic per candidate, filled on first suspension (exact
    // and budget-independent, so one computation serves every rung).
    let mut traffic: Vec<Option<u64>> = vec![None; n];
    // Workers persist across rungs *and* into survivor finalization; the
    // suspended states live in one shared store, so the checkpoint a
    // worker takes in one pass can be resumed by *any* worker in the
    // next (the work-stealing queue makes no locality promise).
    let store = CkptStore::new();
    let mut workers: Vec<HalvingWorker<'_>> =
        (0..threads).map(|_| HalvingWorker::new(&store)).collect();

    for &budget in &schedule.budgets {
        let undecided = undecided_indices(&states);
        if undecided.is_empty() {
            break;
        }
        let screened = run_pass(&mut workers, &undecided, |w, i| {
            let (wi, cfg) = &candidates[i];
            screen_candidate(w, i, cfg, &workloads[*wi], budget, eval_hz, resume)
        });
        for (i, outcome) in screened {
            states[i] = match outcome {
                ScreenOutcome::Skip => {
                    hstats.skipped += 1;
                    State::Skipped
                }
                ScreenOutcome::Exact(p) => {
                    hstats.screen_exact += 1;
                    State::Exact(p)
                }
                ScreenOutcome::Partial(mut sc) => {
                    if traffic_axis {
                        let (wi, cfg) = &candidates[i];
                        // A suspended run loaded its program, so the
                        // compile cannot fail here.
                        sc.traffic = *traffic[i].get_or_insert_with(|| {
                            FunctionalModel::new(cfg, &workloads[*wi])
                                .map(|fm| fm.expected_offchip_reads())
                                .unwrap_or(0)
                        });
                    }
                    State::Undecided(Some(sc))
                }
            };
        }
        hstats.pruned += prune_dominated(&mut states, &widx, &group_outputs, traffic_axis);
        // Checkpoints of decided candidates are dead weight; drop them.
        store.retain(|i| matches!(states[i], State::Undecided(_)));
    }

    // Completion runs for the survivors, resumed from their last
    // screening checkpoint instead of restarting.
    let survivors = undecided_indices(&states);
    let finished = run_pass(&mut workers, &survivors, |w, i| {
        let (wi, cfg) = &candidates[i];
        finish_candidate(w, i, cfg, &workloads[*wi], eval_hz, resume)
    });
    for (i, res) in finished {
        states[i] = match res {
            Some(p) => {
                hstats.full_runs += 1;
                State::Exact(p)
            }
            None => {
                hstats.skipped += 1;
                State::Skipped
            }
        };
    }
    for w in &workers {
        hstats.resumed_cycles += w.resumed_cycles;
        hstats.saved_cycles += w.saved_cycles;
        hstats.worker_items.push(w.items);
        hstats.steals += w.steals;
    }

    let points: Vec<DesignPoint> = states
        .into_iter()
        .enumerate()
        .filter_map(|(i, s)| match s {
            State::Exact(mut p) => {
                if let Some(ms) = mappings {
                    p.mapping = Some(ms[widx[i]]);
                }
                Some(p)
            }
            _ => None,
        })
        .collect();
    Ok(HalvingOutcome {
        points: finalize_axes(points, traffic_axis),
        pruned: bound_pruned,
        stats: hstats,
    })
}

/// Work accounting of a joint mapping × hierarchy sweep.
/// Invariant: `enumerated == bound_pruned + simulated + memo_hits +
/// skipped` — every candidate is pruned analytically, simulated as a
/// class representative, scored off a class-mate's run, or skipped.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct JointStats {
    /// *(mapping, config)* candidates enumerated.
    pub enumerated: usize,
    /// Candidates dropped analytically (never simulated).
    pub bound_pruned: usize,
    /// Behavioral-class representatives actually simulated.
    pub simulated: usize,
    /// Candidates scored from a class-mate's simulation instead of their
    /// own (the compile-memoization win).
    pub memo_hits: usize,
    /// Candidates whose program fails to compile or simulate.
    pub skipped: usize,
    /// Lower bound on the simulated cycles the analytical prunes avoided.
    pub cycles_saved_lb: u64,
    /// Internal cycles actually simulated (representatives only) — the
    /// denominator-side of the ≥5× work-saving claim `benches/dse_joint`
    /// gates against the naive nested sweep.
    pub sim_cycles: u64,
}

/// Result of a joint sweep: exactly-scored points over the four-axis
/// (area, power, cycles, off-chip reads) front, every point carrying its
/// [`Mapping`]; analytically pruned candidates flagged, never vanished.
#[derive(Debug, Clone)]
pub struct JointExplore {
    /// Exactly-scored design points, front marked, sorted by area.
    pub points: Vec<DesignPoint>,
    /// Candidates the analytical prescreen dropped (bound-scored, in
    /// enumeration order, mapping attached).
    pub pruned: Vec<PrunedPoint>,
    /// Work accounting.
    pub stats: JointStats,
}

/// The naive nested joint sweep: simulate **every** *(mapping, config)*
/// candidate, no pruning, no memoization. The differential baseline the
/// pruned+memoized path must match bit for bit on the front, and the
/// cost baseline `benches/dse_joint` measures the ≥5× saving against.
pub fn explore_joint_naive(joint: &JointSpace) -> Result<JointExplore> {
    let mut session = EvalSession::new();
    let mut stats = JointStats::default();
    let mut points = Vec::new();
    for (wi, cfg) in joint.candidates() {
        stats.enumerated += 1;
        match session.evaluate(cfg, &joint.workloads[wi], joint.space.eval_hz) {
            Some(mut p) => {
                p.mapping = Some(joint.mappings[wi]);
                stats.sim_cycles += p.cycles;
                points.push(p);
            }
            None => stats.skipped += 1,
        }
    }
    stats.simulated = points.len();
    Ok(JointExplore { points: finalize_axes(points, true), pruned: Vec::new(), stats })
}

/// Explore a joint mapping × hierarchy space with analytic pruning and
/// compile memoization (serial; the pooled variant is
/// [`super::pool::HierarchyPool::explore_joint`]).
///
/// Candidates stream through the joint prescreen
/// ([`crate::dse::bound`]) — interval dominance now over (area, cycles,
/// power, **traffic**), with the off-chip-read count exact on both ends
/// of the interval — and the survivors are grouped into behavioral
/// classes: equal behavior key **and** equal compiled [`McuProgram`]
/// simulate bit-identically even across *different mappings*, so each
/// class pays for exactly one representative run and every member is
/// scored from those shared stats with its own exact area/power. The
/// marked four-axis front is bitwise identical to
/// [`explore_joint_naive`]'s.
///
/// [`McuProgram`]: crate::mem::McuProgram
pub fn explore_joint(joint: &JointSpace) -> Result<JointExplore> {
    joint_explore_impl(joint, 1)
}

/// Shared serial/pooled joint explorer (see [`explore_joint`]). Classes
/// form in enumeration order and representatives are scored back in
/// class order, so results are independent of `threads`.
pub(crate) fn joint_explore_impl(joint: &JointSpace, threads: usize) -> Result<JointExplore> {
    use super::bound::Survivor;

    let outcome = joint_prescreen(joint);
    let mut stats = JointStats {
        enumerated: outcome.stats.enumerated,
        bound_pruned: outcome.stats.bound_pruned,
        skipped: outcome.stats.skipped,
        cycles_saved_lb: outcome.stats.cycles_saved_lb,
        ..Default::default()
    };
    // Group survivors into behavioral classes. The first member of a
    // class (smallest enumeration index — survivors arrive in order) is
    // its representative.
    let mut class_ids: BTreeMap<super::bound::BehaviorKey, Vec<usize>> = BTreeMap::new();
    let mut classes: Vec<Vec<Survivor>> = Vec::new();
    for s in outcome.survivors {
        let ids = class_ids.entry(s.key.clone()).or_default();
        match ids.iter().find(|&&cid| classes[cid][0].prog == s.prog) {
            Some(&cid) => classes[cid].push(s),
            None => {
                ids.push(classes.len());
                classes.push(vec![s]);
            }
        }
    }
    // One simulation per class (representatives in class order).
    let rep_stats: Vec<Option<SimStats>> = if threads <= 1 {
        let mut sess = EvalSession::new();
        classes
            .iter()
            .map(|c| sess.run_stats(&c[0].cfg, &joint.workloads[c[0].widx]))
            .collect()
    } else {
        crate::util::par_map_indexed_with(classes.len(), threads, EvalSession::new, |sess, i| {
            let r = &classes[i][0];
            sess.run_stats(&r.cfg, &joint.workloads[r.widx])
        })
    };
    let mut scored: Vec<(usize, DesignPoint)> = Vec::new();
    for (class, st) in classes.iter().zip(&rep_stats) {
        match st {
            Some(rs) => {
                stats.simulated += 1;
                stats.sim_cycles += rs.internal_cycles;
                stats.memo_hits += class.len() - 1;
                for m in class {
                    // Cycles, efficiency and traffic are shared class-wide
                    // (the runs are bit-identical); area and power come
                    // from the member's own config.
                    let mut p = score(m.cfg.clone(), rs, joint.space.eval_hz);
                    p.mapping = Some(joint.mappings[m.widx]);
                    scored.push((m.index, p));
                }
            }
            // A representative the simulator skips decides its whole
            // class: behavior-equal members fail the same way.
            None => stats.skipped += class.len(),
        }
    }
    scored.sort_by_key(|&(i, _)| i);
    let points: Vec<DesignPoint> = scored.into_iter().map(|(_, p)| p).collect();
    Ok(JointExplore { points: finalize_axes(points, true), pruned: outcome.pruned, stats })
}

/// Successive halving over a joint space: the halving engine
/// ([`halving_core`]) with per-mapping workloads, workload-grouped
/// screened pruning, and the traffic axis on. Serial; pooled variant on
/// [`super::pool::HierarchyPool`].
pub fn explore_joint_halving(
    joint: &JointSpace,
    schedule: &HalvingSchedule,
) -> Result<HalvingOutcome> {
    joint_halving_impl(joint, schedule, 1, false)
}

/// [`explore_joint_halving`] behind the joint analytical prescreen: the
/// rungs only ever see bound-and-prune survivors, and the accounting
/// invariant extends to `screen_exact + pruned + full_runs + skipped +
/// bound_pruned == candidates` over the joint enumeration.
pub fn explore_joint_halving_pruned(
    joint: &JointSpace,
    schedule: &HalvingSchedule,
) -> Result<HalvingOutcome> {
    joint_halving_impl(joint, schedule, 1, true)
}

/// Shared serial/pooled joint-halving implementation.
pub(crate) fn joint_halving_impl(
    joint: &JointSpace,
    schedule: &HalvingSchedule,
    threads: usize,
    prune: bool,
) -> Result<HalvingOutcome> {
    let (candidates, bound_pruned, hstats) = if prune {
        let outcome = joint_prescreen(joint);
        let hstats = HalvingStats {
            candidates: outcome.stats.enumerated,
            skipped: outcome.stats.skipped,
            bound_pruned: outcome.stats.bound_pruned,
            bound_cycles_saved: outcome.stats.cycles_saved_lb,
            ..Default::default()
        };
        let candidates = outcome.survivors.into_iter().map(|s| (s.widx, s.cfg)).collect();
        (candidates, outcome.pruned, hstats)
    } else {
        let candidates: Vec<(usize, HierarchyConfig)> = joint.candidates().collect();
        let hstats = HalvingStats { candidates: candidates.len(), ..Default::default() };
        (candidates, Vec::new(), hstats)
    };
    halving_core(
        candidates,
        &joint.workloads,
        Some(&joint.mappings),
        schedule,
        threads,
        true,
        joint.space.eval_hz,
        true,
        bound_pruned,
        hstats,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_space() -> SearchSpace {
        SearchSpace {
            depths: vec![1, 2],
            ram_depths: vec![32, 128],
            word_widths: vec![32],
            level_kinds: vec![KindChoice::Standard],
            try_dual_ported: true,
            protections: vec![Protection::None],
            eval_hz: 100e6,
        }
    }

    #[test]
    fn explore_finds_points_and_front() {
        let pts = explore(&small_space(), &PatternProgram::cyclic(0, 64).with_outputs(640)).unwrap();
        assert!(pts.len() >= 4, "got {} points", pts.len());
        assert!(pts.iter().any(|p| p.on_front));
        // Front members are not dominated: quick spot check.
        for p in pts.iter().filter(|p| p.on_front) {
            for q in &pts {
                let dom = q.area < p.area && q.power < p.power && q.cycles < p.cycles;
                assert!(!dom, "front point dominated");
            }
        }
    }

    #[test]
    fn bigger_memory_buys_speed_on_large_windows() {
        // For a window of 128, configs whose last level holds it run ~2x
        // faster than those that stream (Fig 5 economics).
        let pts = explore(&small_space(), &PatternProgram::cyclic(0, 128).with_outputs(1_280)).unwrap();
        let fits = pts
            .iter()
            .filter(|p| p.config.last_level().capacity_words() >= 128)
            .map(|p| p.cycles)
            .min()
            .unwrap();
        let streams = pts
            .iter()
            .filter(|p| p.config.levels.iter().all(|l| l.capacity_words() < 128))
            .map(|p| p.cycles)
            .min();
        if let Some(st) = streams {
            assert!(st as f64 > 1.5 * fits as f64, "fits {fits} vs streams {st}");
        }
    }

    #[test]
    fn enumeration_respects_depth_monotonicity() {
        for cfg in enumerate(&small_space()) {
            let depths: Vec<u64> = cfg.levels.iter().map(|l| l.ram_depth).collect();
            assert!(depths.windows(2).all(|w| w[1] <= w[0]), "{depths:?}");
        }
    }

    #[test]
    fn kind_odometer_enumerates_every_combination() {
        use crate::config::LevelKind;
        let mut space = small_space();
        space.level_kinds = vec![KindChoice::Standard, KindChoice::DoubleBuffered];
        let cfgs = enumerate(&space);
        // Restricting to standard kinds must reproduce a subsequence, and
        // the full enumeration must cover mixed-kind stacks.
        let std_only = enumerate(&small_space());
        assert!(cfgs.len() > std_only.len());
        for c in &std_only {
            assert!(cfgs.contains(c), "standard candidate missing from kinds sweep");
        }
        let db_count = |c: &crate::config::HierarchyConfig| {
            c.levels.iter().filter(|l| l.kind == LevelKind::DoubleBuffered).count()
        };
        assert!(cfgs.iter().any(|c| db_count(c) == c.levels.len()), "all-DB stack present");
        assert!(
            cfgs.iter().any(|c| c.levels.len() == 2 && db_count(c) == 1),
            "mixed stack present"
        );
        // Double-buffered last levels take no port variants: exactly one
        // candidate per (depth-stack, kinds) combination ending in DB.
        let all_db_depth1: Vec<_> = cfgs
            .iter()
            .filter(|c| c.levels.len() == 1 && db_count(c) == 1)
            .collect();
        assert_eq!(all_db_depth1.len(), space.ram_depths.len());
    }

    /// The recursive enumeration the streaming odometer replaced, kept as
    /// the differential reference for the emission-order contract
    /// (lexicographic; level 0 most significant).
    fn enumerate_recursive(space: &SearchSpace) -> Vec<HierarchyConfig> {
        fn emit(
            space: &SearchSpace,
            w: u32,
            stack: &[u64],
            kinds: &[KindChoice],
            out: &mut Vec<HierarchyConfig>,
        ) {
            let last_standard = matches!(kinds.last(), Some(KindChoice::Standard));
            let port_options: &[u32] =
                if last_standard && space.try_dual_ported { &[1, 2] } else { &[1] };
            for &last_ports in port_options {
                let mut b = HierarchyConfig::builder().offchip(32, 24, 1.0);
                for (i, (&d, &k)) in stack.iter().zip(kinds.iter()).enumerate() {
                    b = match k {
                        KindChoice::Standard => {
                            let ports = if i + 1 == stack.len() { last_ports } else { 1 };
                            b.level(w, d, 1, ports)
                        }
                        KindChoice::DoubleBuffered => b.level_double_buffered(w, d),
                    };
                }
                if w > 32 {
                    b = b.osr(w.max(64), vec![32]);
                }
                if let Ok(cfg) = b.build() {
                    out.push(cfg);
                }
            }
        }
        fn descend_kinds(
            space: &SearchSpace,
            w: u32,
            stack: &[u64],
            kinds: &mut Vec<KindChoice>,
            out: &mut Vec<HierarchyConfig>,
        ) {
            if kinds.len() == stack.len() {
                emit(space, w, stack, kinds, out);
                return;
            }
            for &k in &space.level_kinds {
                kinds.push(k);
                descend_kinds(space, w, stack, kinds, out);
                kinds.pop();
            }
        }
        fn descend(
            space: &SearchSpace,
            w: u32,
            remaining: usize,
            scratch: &mut Vec<u64>,
            kinds: &mut Vec<KindChoice>,
            out: &mut Vec<HierarchyConfig>,
        ) {
            if remaining == 0 {
                let stack = scratch.clone();
                descend_kinds(space, w, &stack, kinds, out);
                return;
            }
            for &d in &space.ram_depths {
                let monotone = scratch.last().map(|&prev| d <= prev).unwrap_or(true);
                if monotone {
                    scratch.push(d);
                    descend(space, w, remaining - 1, scratch, kinds, out);
                    scratch.pop();
                }
            }
        }
        let mut out = Vec::new();
        let mut scratch = Vec::new();
        let mut kinds = Vec::new();
        for &w in &space.word_widths {
            for &nl in &space.depths {
                descend(space, w, nl, &mut scratch, &mut kinds, &mut out);
            }
        }
        out
    }

    #[test]
    fn protection_dimension_is_fastest_and_uniform() {
        // Appending protection menus multiplies the space by the menu
        // size, with protection the fastest digit: consecutive candidates
        // walk the menu while the rest of the config holds still, and
        // every level of a candidate carries the same scheme.
        let base = small_space();
        let mut protected = small_space();
        protected.protections = vec![Protection::None, Protection::Parity, Protection::Secded];
        let plain: Vec<HierarchyConfig> = base.candidates().collect();
        let swept: Vec<HierarchyConfig> = protected.candidates().collect();
        assert_eq!(swept.len(), 3 * plain.len());
        for (i, cfg) in swept.iter().enumerate() {
            let want = protected.protections[i % 3];
            assert!(cfg.levels.iter().all(|l| l.protection == want), "candidate {i}");
            // Stripping the protection digit recovers the plain sequence.
            let mut stripped = cfg.clone();
            for l in &mut stripped.levels {
                l.protection = Protection::None;
            }
            assert_eq!(stripped, plain[i / 3], "candidate {i}");
        }
        // The default single-entry menu leaves the enumeration untouched.
        assert_eq!(plain, enumerate_recursive(&base));
    }

    #[test]
    fn streaming_iterator_matches_recursive_reference() {
        // Full kind menu, dual ports, multiple widths (OSR path), three
        // level counts, and an unsorted depth menu with a duplicate: the
        // odometer must reproduce the recursive order for any menu.
        let mut space = small_space();
        space.level_kinds = vec![KindChoice::Standard, KindChoice::DoubleBuffered];
        space.word_widths = vec![32, 128];
        space.depths = vec![1, 2, 3];
        space.ram_depths = vec![128, 32, 128, 64];
        let streamed: Vec<HierarchyConfig> = space.candidates().collect();
        let recursive = enumerate_recursive(&space);
        assert!(streamed.len() > 100, "space must be non-trivial: {}", streamed.len());
        assert_eq!(streamed, recursive);
        // And the iterator is resumable state, not a collected list: two
        // walks agree.
        assert_eq!(space.candidates().count(), streamed.len());
    }

    fn assert_points_identical(a: &[DesignPoint], b: &[DesignPoint]) {
        assert_eq!(a.len(), b.len(), "point counts differ");
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.config, y.config);
            assert_eq!(x.area.to_bits(), y.area.to_bits());
            assert_eq!(x.power.to_bits(), y.power.to_bits());
            assert_eq!(x.cycles, y.cycles);
            assert_eq!(x.efficiency.to_bits(), y.efficiency.to_bits());
            assert_eq!(x.on_front, y.on_front);
            assert_eq!(x.offchip_reads, y.offchip_reads);
            assert_eq!(x.mapping, y.mapping);
        }
    }

    #[test]
    fn warm_explore_matches_cold_evaluation_bitwise() {
        // The warm serial explorer (one session re-armed per candidate)
        // must equal the cold reference (a fresh hierarchy per candidate)
        // bit for bit.
        let space = small_space();
        let w = PatternProgram::cyclic(0, 64).with_outputs(640);
        let warm = explore(&space, &w).unwrap();
        let cold = finalize(
            enumerate(&space)
                .into_iter()
                .filter_map(|cfg| evaluate(cfg, &w, space.eval_hz))
                .collect(),
        );
        assert!(warm.len() >= 4, "space must be non-trivial");
        assert_points_identical(&warm, &cold);
    }

    /// Seeded space for the successive-halving equality tests: constant
    /// steady-state rates (pure cyclic window) and strict area ordering,
    /// so screened dominance is faithful to final dominance.
    fn halving_space() -> SearchSpace {
        SearchSpace {
            depths: vec![1, 2],
            ram_depths: vec![32, 128, 1024],
            word_widths: vec![32],
            level_kinds: vec![KindChoice::Standard],
            try_dual_ported: false,
            protections: vec![Protection::None],
            eval_hz: 100e6,
        }
    }

    #[test]
    fn halving_front_matches_exhaustive() {
        let space = halving_space();
        let w = PatternProgram::cyclic(0, 256).with_outputs(2_560);
        let exhaustive = explore(&space, &w).unwrap();
        let halved =
            explore_halving(&space, &w, &HalvingSchedule::for_workload(&w)).unwrap();
        let ef: Vec<DesignPoint> =
            exhaustive.iter().filter(|p| p.on_front).cloned().collect();
        let hf: Vec<DesignPoint> =
            halved.points.iter().filter(|p| p.on_front).cloned().collect();
        assert!(!ef.is_empty());
        assert_points_identical(&ef, &hf);
    }

    #[test]
    fn pruned_explore_front_matches_exhaustive_bitwise() {
        // The analytical prescreen may only drop candidates provably off
        // the exact front, so the marked fronts must be bitwise equal on
        // every seeded space — including one with an all-fitting workload
        // where mechanism 2 collapses most of the space.
        let kinds_space = {
            let mut s = small_space();
            s.level_kinds = vec![KindChoice::Standard, KindChoice::DoubleBuffered];
            s
        };
        for (space, w) in [
            (small_space(), PatternProgram::cyclic(0, 64).with_outputs(640)),
            (halving_space(), PatternProgram::cyclic(0, 48).with_outputs(480)),
            (kinds_space, PatternProgram::cyclic(0, 64).with_outputs(640)),
        ] {
            let exhaustive = explore(&space, &w).unwrap();
            let pruned = explore_pruned(&space, &w).unwrap();
            let ef: Vec<DesignPoint> =
                exhaustive.iter().filter(|p| p.on_front).cloned().collect();
            let pf: Vec<DesignPoint> =
                pruned.points.iter().filter(|p| p.on_front).cloned().collect();
            assert!(!ef.is_empty());
            assert_points_identical(&ef, &pf);
            // The ledger balances: every enumerated candidate is a scored
            // point, a flagged prune, or a skip — nothing vanishes.
            assert_eq!(pruned.stats.enumerated, enumerate(&space).len());
            assert_eq!(
                pruned.stats.enumerated,
                pruned.points.len() + pruned.pruned.len() + pruned.stats.skipped,
                "{:?}",
                pruned.stats
            );
            assert_eq!(pruned.stats.simulated, pruned.points.len());
            assert_eq!(pruned.stats.bound_pruned, pruned.pruned.len());
        }
    }

    #[test]
    fn pruned_halving_front_matches_exhaustive_bitwise() {
        let space = halving_space();
        let w = PatternProgram::cyclic(0, 256).with_outputs(2_560);
        let exhaustive = explore(&space, &w).unwrap();
        let halved =
            explore_halving_pruned(&space, &w, &HalvingSchedule::for_workload(&w)).unwrap();
        let ef: Vec<DesignPoint> =
            exhaustive.iter().filter(|p| p.on_front).cloned().collect();
        let hf: Vec<DesignPoint> =
            halved.points.iter().filter(|p| p.on_front).cloned().collect();
        assert!(!ef.is_empty());
        assert_points_identical(&ef, &hf);
        let s = &halved.stats;
        assert_eq!(s.candidates, enumerate(&space).len());
        assert_eq!(
            s.screen_exact + s.pruned + s.full_runs + s.skipped + s.bound_pruned,
            s.candidates,
            "prune-aware accounting must cover every candidate: {s:?}"
        );
        assert_eq!(halved.pruned.len(), s.bound_pruned);
        assert_eq!(
            s.bound_cycles_saved,
            halved.pruned.iter().map(|p| p.score.cycles_lb).sum::<u64>()
        );
        // Un-pruned halving reports zeros in the new columns.
        let plain = explore_halving(&space, &w, &HalvingSchedule::for_workload(&w)).unwrap();
        assert_eq!(plain.stats.bound_pruned, 0);
        assert_eq!(plain.stats.bound_cycles_saved, 0);
        assert!(plain.pruned.is_empty());
    }

    #[test]
    fn halving_accounts_all_candidates_and_prunes() {
        let space = halving_space();
        let w = PatternProgram::cyclic(0, 256).with_outputs(2_560);
        let halved =
            explore_halving(&space, &w, &HalvingSchedule::for_workload(&w)).unwrap();
        let s = &halved.stats;
        assert_eq!(s.candidates, enumerate(&space).len());
        assert_eq!(
            s.screen_exact + s.pruned + s.full_runs + s.skipped + s.bound_pruned,
            s.candidates,
            "accounting must cover every candidate: {s:?}"
        );
        assert!(s.pruned > 0, "dominated candidates should be pruned: {s:?}");
        assert_eq!(halved.points.len(), s.screen_exact + s.full_runs);
    }

    #[test]
    fn resume_matches_restart_and_saves_cycles() {
        // Incremental (checkpoint-resumed) halving must produce the exact
        // point list the restart strategy produces — only the cycle
        // accounting may differ, and it must show inherited work.
        let space = halving_space();
        let w = PatternProgram::cyclic(0, 256).with_outputs(2_560);
        let schedule = HalvingSchedule::for_workload(&w);
        let resumed = explore_halving(&space, &w, &schedule).unwrap();
        let restarted = explore_halving_restart(&space, &w, &schedule).unwrap();
        assert_points_identical(&resumed.points, &restarted.points);
        assert_eq!(resumed.stats.candidates, restarted.stats.candidates);
        assert_eq!(resumed.stats.screen_exact, restarted.stats.screen_exact);
        assert_eq!(resumed.stats.pruned, restarted.stats.pruned);
        assert_eq!(resumed.stats.full_runs, restarted.stats.full_runs);
        assert_eq!(resumed.stats.skipped, restarted.stats.skipped);
        assert_eq!(restarted.stats.saved_cycles, 0, "restart inherits nothing");
        assert_eq!(restarted.stats.resumed_cycles, 0);
        assert!(
            resumed.stats.saved_cycles > 0,
            "resume must inherit screened prefixes: {:?}",
            resumed.stats
        );
        assert!(resumed.stats.resumed_cycles > 0, "{:?}", resumed.stats);
    }

    #[test]
    fn halving_reports_worker_utilization() {
        let space = halving_space();
        let w = PatternProgram::cyclic(0, 256).with_outputs(2_560);
        let schedule = HalvingSchedule::for_workload(&w);
        let serial = explore_halving(&space, &w, &schedule).unwrap();
        assert_eq!(serial.stats.worker_items.len(), 1, "one worker when serial");
        assert_eq!(serial.stats.steals, 0, "a serial pass cannot steal");
        // Every screening and completion evaluation is tallied; each
        // candidate is evaluated at least once (rung 1 sees all of them).
        let total: u64 = serial.stats.worker_items.iter().sum();
        assert!(total >= serial.stats.candidates as u64, "{:?}", serial.stats);
        // The evaluation count is a pure function of the deterministic
        // decisions, so it is identical for any worker count — only its
        // distribution over workers may shift.
        let pooled = halving_impl(&space, &w, &schedule, 3, true, false).unwrap();
        assert_eq!(pooled.stats.worker_items.len(), 3);
        assert_eq!(pooled.stats.worker_items.iter().sum::<u64>(), total);
        assert_eq!(serial.stats, pooled.stats, "equality excludes scheduling diagnostics");
    }

    #[test]
    fn empty_schedule_degenerates_to_exhaustive() {
        let space = small_space();
        let w = PatternProgram::shifted_cyclic(0, 64, 16).with_outputs(640);
        let exhaustive = explore(&space, &w).unwrap();
        let halved =
            explore_halving(&space, &w, &HalvingSchedule { budgets: Vec::new() }).unwrap();
        assert_points_identical(&exhaustive, &halved.points);
        assert_eq!(halved.stats.pruned, 0);
        assert_eq!(halved.stats.screen_exact, 0);
    }
}

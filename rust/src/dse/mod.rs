//! Design-space exploration over hierarchy configurations (§1, §4: the
//! framework is meant to be driven by DSE tools like ZigZag; this module
//! provides the semi-automatic search the paper describes).
//!
//! The explorer enumerates configurations (levels × depths × widths ×
//! level kinds × ports × OSR — the per-level [`KindChoice`] makes the
//! §6 double-buffered scheme an explorable dimension, following the
//! capacity/communication co-exploration argument of Cocco et al.),
//! scores each by simulating a target pattern workload, and
//! reports the area/power/runtime Pareto front. Scoring runs on warm
//! per-worker sessions (one hierarchy re-armed per candidate, never
//! reallocated) and is deterministic and per-candidate independent, so
//! [`pool::HierarchyPool`] fans the sweep out across threads with a
//! bitwise-identical result. [`explore_halving`] adds a
//! successive-halving schedule with **incremental screening**: each
//! undecided candidate is suspended into a
//! [`crate::mem::HierarchyCheckpoint`] at the end of a rung and resumed
//! at the next, so a rung simulates only the budget *delta*, screened-
//! dominated candidates are dropped between rungs, and survivors resume
//! to completion — every simulated cycle is paid exactly once, with the
//! inherited/extra work reported in [`HalvingStats`]
//! (`saved_cycles`/`resumed_cycles`). [`explore_halving_restart`] keeps
//! the re-run-from-scratch strategy as the measurable baseline.
//! [`shard::explore_halving_sharded`] runs the same sweep across
//! **worker processes** (the `dse-worker` subcommand), shipping
//! suspended candidates through the checkpoint wire format
//! ([`crate::mem::wire`]) with work-stealing dispatch and crash
//! recovery — bitwise-identical fronts at near-linear shard scaling.

pub mod pareto;
pub mod pool;
pub mod search;
pub mod shard;

pub use pareto::{pareto_front, Dominance};
pub use pool::{explore_parallel, HierarchyPool};
pub use search::{
    explore, explore_halving, explore_halving_restart, ff_totals, DesignPoint, HalvingOutcome,
    HalvingSchedule, HalvingStats, KindChoice, SearchSpace,
};
pub use shard::{explore_halving_sharded, run_worker, ShardOptions};

//! Design-space exploration over hierarchy configurations (§1, §4: the
//! framework is meant to be driven by DSE tools like ZigZag; this module
//! provides the semi-automatic search the paper describes).
//!
//! The explorer enumerates configurations (levels × depths × widths ×
//! level kinds × ports × OSR — the per-level [`KindChoice`] makes the
//! §6 double-buffered scheme an explorable dimension, following the
//! capacity/communication co-exploration argument of Cocco et al.),
//! scores each by simulating a target pattern workload, and
//! reports the area/power/runtime Pareto front. Enumeration is **lazy**
//! ([`SearchSpace::candidates`], a constant-memory odometer iterator), so
//! million-candidate spaces stream instead of materializing. Scoring
//! runs on warm per-worker sessions (one hierarchy re-armed per
//! candidate, never reallocated) and is deterministic and per-candidate
//! independent, so [`pool::HierarchyPool`] fans the sweep out across
//! threads with a bitwise-identical result. [`explore_halving`] adds a
//! successive-halving schedule with **incremental screening**: each
//! undecided candidate is suspended into a
//! [`crate::mem::HierarchyCheckpoint`] at the end of a rung and resumed
//! at the next, so a rung simulates only the budget *delta*, screened-
//! dominated candidates are dropped between rungs, and survivors resume
//! to completion — every simulated cycle is paid exactly once, with the
//! inherited/extra work reported in [`HalvingStats`]
//! (`saved_cycles`/`resumed_cycles`). [`explore_halving_restart`] keeps
//! the re-run-from-scratch strategy as the measurable baseline.
//! [`shard::explore_halving_sharded`] runs the same sweep across
//! **worker processes** (the `dse-worker` subcommand), shipping
//! suspended candidates through the checkpoint wire format
//! ([`crate::mem::wire`]) with work-stealing dispatch and crash
//! recovery — bitwise-identical fronts at near-linear shard scaling.
//!
//! # The dimension list
//!
//! A search space is an ordered list of [`dims::Dim`] values — word
//! width, level count, depth stack, level kinds, last-level ports, and
//! (for joint spaces) the loop-nest **mapping** — with earlier entries
//! the slower odometer digits. [`SearchSpace`] keeps its familiar
//! concrete fields, but enumeration goes through the list
//! ([`SearchSpace::dims`] → [`Candidates::from_dims`]), so a new
//! dimension composes with the existing lazy constant-memory odometer
//! instead of growing bespoke fields; an off-chip-backend dimension is
//! the planned next rider (see ROADMAP). [`dims::JointSpace`] prepends a
//! [`dims::Mapping`] dimension (spatial unrolling × temporal loop
//! order) over one layer: each mapping's weight-stream workload is
//! *derived and verified* ([`dims::mapping_workload`]), every candidate
//! becomes a *(mapping, config)* pair, and the front gains **off-chip
//! reads** as a fourth axis ([`explore_joint`], [`explore_joint_halving`],
//! [`shard::explore_joint_sharded`]; the naive differential baseline is
//! [`explore_joint_naive`]).
//!
//! # Bound-and-prune: soundness
//!
//! [`explore_pruned`], [`explore_halving_pruned`], the pooled variants,
//! and [`ShardOptions::prune`] all put the analytical prescreen
//! ([`bound`]) in front of the cycle-accurate paths. The contract is
//! that the **exact Pareto front is bitwise-identical to the exhaustive
//! sweep's** on every space, not merely close; pruned candidates are
//! returned bound-scored and flagged ([`PrunedPoint`]), never silently
//! vanished. The argument:
//!
//! 1. **Exact area, bounded cycles/power.** A candidate's area comes
//!    from the same cost model the exact sweep scores with — no bound
//!    involved. Its cycles are bracketed by the admissible
//!    [`crate::mem::FunctionalModel::cycle_lower_bound`] /
//!    [`crate::mem::FunctionalModel::cycle_upper_bound`] (property-tested
//!    against simulation across the pattern-family × level-kind ×
//!    clock-ratio matrix in `tests/bounds.rs`), and its power by the
//!    exact closed-form event counts evaluated at those two cycle counts
//!    (average power is monotone non-increasing in the cycle count at
//!    fixed event counts — leakage is time-rate-constant and dynamic
//!    energy is fixed, so more cycles only dilute it). On joint sweeps
//!    the fourth axis, off-chip reads, is **exact on both ends of the
//!    interval**: the count is a pure function of the compiled program
//!    and the level geometry
//!    ([`crate::mem::FunctionalModel::expected_offchip_reads`],
//!    property-tested against simulated `offchip_reads` in
//!    `tests/joint.rs`), so adding it can only expose more true losers,
//!    never misjudge one.
//! 2. **Interval dominance prunes only true losers.** Candidate `p` is
//!    dropped only if some enumerated witness `q` satisfies
//!    `area(q) ≤ area(p)`, `cycles_ub(q) ≤ cycles_lb(p)`,
//!    `power_ub(q) ≤ power_lb(p)` — and, with the traffic axis on,
//!    `reads(q) ≤ reads(p)` — strictly on area or cycles. Wherever
//!    the true values land inside their intervals, `q`'s exact point
//!    weakly dominates `p`'s with one strict axis — so the exhaustive
//!    sweep would not have put `p` on the front either. Ties are never
//!    pruned (the exhaustive front keeps duplicates, so must we). The
//!    witness itself need not survive: if `q` is in turn pruned, its
//!    own witness dominates `p` transitively, and the chain terminates
//!    at a minimal (unprunable) point because strict dominance is a
//!    strict partial order on a finite set. Hence removing pruned
//!    points changes no other point's front membership.
//! 3. **Behavioral equivalence prunes only true losers.** Candidates
//!    differing only in the depths of standard levels the fetch stream
//!    never wraps compile to the *same* program and simulate
//!    bit-identically (depth enters level behavior only through pointer
//!    wraps and occupancy). Within such a class, cycles are shared and
//!    area plus the per-level power coefficients are known exactly, so
//!    a member beaten on all of them (area strictly) by a class sibling
//!    is dominated at whatever the shared outcome turns out to be.
//!    Classes deliberately carry no workload identity: two *(mapping,
//!    config)* candidates with equal behavior key **and** equal compiled
//!    program replay the same fetch stream, so joint classes soundly
//!    span mappings — one representative simulation scores the whole
//!    class (cycles, efficiency, and traffic shared; area/power from
//!    each member's own config), counted as `memo_hits` in
//!    [`JointStats`].
//! 4. **Order independence.** The prescreen is two-pass (Kung-style):
//!    pass one streams the enumeration, pruning on arrival while
//!    recording every valid candidate as a witness; pass two re-filters
//!    the pass-one survivors against the *final* witness frontier and
//!    classes. A candidate's verdict therefore depends only on the
//!    candidate *set*, not the emission order.
//! 5. **Composition with halving and sharding.** The prescreen runs
//!    before rung 0 and only ever *removes* provably-dominated
//!    candidates from the rung state machine; the rungs' own screened
//!    prune rule sees fewer potential dominators, never more, so it can
//!    only prune less — the determinism contract (serial == pooled ==
//!    sharded, bitwise, for any thread/shard count) is untouched, since
//!    the prescreen itself is deterministic and runs identically on the
//!    coordinator.
//!
//! One caveat is inherited rather than introduced: a pruning witness is
//! assumed to actually *simulate* (not deadlock). Every compilable
//! configuration the simulator accepts runs to completion on the §3.2
//! pattern families — candidates whose program fails to compile are
//! counted `skipped` by prescreen and exact paths alike and are never
//! used as witnesses.

pub mod bound;
pub mod dims;
pub mod pareto;
pub mod pool;
pub mod search;
pub mod shard;

pub use bound::{BoundScore, PruneStats, PrunedPoint};
pub use dims::{mapping_workload, Dim, JointCandidates, JointSpace, Mapping};
pub use pareto::{pareto_front, BoundFrontier, Dominance};
pub use pool::{explore_parallel, HierarchyPool};
pub use search::{
    explore, explore_halving, explore_halving_pruned, explore_halving_restart, explore_joint,
    explore_joint_halving, explore_joint_halving_pruned, explore_joint_naive, explore_pruned,
    ff_totals, Candidates, DesignPoint, HalvingOutcome, HalvingSchedule, HalvingStats, JointExplore,
    JointStats, KindChoice, PrunedExplore, SearchSpace,
};
pub use shard::{
    explore_halving_sharded, explore_joint_sharded, run_worker, run_worker_chaos, ShardOptions,
};

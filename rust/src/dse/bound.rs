//! Analytical bound-and-prune front end for the DSE.
//!
//! Every enumerated candidate is scored **without simulating a single
//! cycle**: exact area from the cost model, admissible cycle bounds from
//! [`FunctionalModel`], and power bounds from the exact closed-form
//! activity counts evaluated at those cycle bounds. Two sound pruning
//! mechanisms then drop candidates that provably cannot be on the exact
//! Pareto front, so the cycle-accurate paths (`explore`, the halving
//! rungs, the shard fleet) only ever see survivors:
//!
//! 1. **Interval dominance** ([`super::pareto::BoundFrontier`]): a
//!    candidate whose *best* case (exact area, cycle lower bound, power
//!    at the upper cycle bound) is dominated by some other enumerated
//!    candidate's *worst* case (exact area, cycle upper bound, power at
//!    the lower cycle bound) loses to that witness's true point no
//!    matter where either lands inside its interval. On joint sweeps the
//!    frontier carries a fourth axis — off-chip reads — which is an
//!    **exact** closed-form event count, so it enters both sides of the
//!    comparison at its true value (see [`crate::dse`] for the extended
//!    soundness argument).
//! 2. **Behavioral equivalence**: candidates that differ only in the
//!    depths of standard levels the fetch stream never wraps compile to
//!    the **same** [`McuProgram`] and simulate bit-identically (depth
//!    enters level behavior only through pointer wraps and occupancy,
//!    all identity below capacity). Within such a class only the power
//!    coefficients and area differ — known exactly — so a member beaten
//!    componentwise on those by a strictly smaller-area member is
//!    dominated at whatever the (shared) simulated outcome turns out to
//!    be.
//!
//! The prescreen is two-pass Kung-style so the emission order cannot
//! matter: pass one streams candidates, pruning on arrival against the
//! frontier/classes built so far while inserting every valid candidate
//! as a witness; pass two re-filters the pass-one survivors against the
//! *final* frontier and classes. See the [`crate::dse`] module docs for
//! the full soundness argument.

use super::dims::{JointSpace, Mapping};
use super::pareto::BoundFrontier;
use super::search::SearchSpace;
use crate::config::{HierarchyConfig, LevelKind};
use crate::cost::{hierarchy_area, level_access_energy, level_leakage, run_power};
use crate::mem::{FunctionalModel, McuProgram};
use crate::pattern::PatternProgram;
use std::collections::BTreeMap;

/// Analytical score of one candidate: exact area plus admissible bounds
/// on cycles and average power, computed without simulation.
#[derive(Debug, Clone, Copy)]
pub struct BoundScore {
    /// Exact chip area (µm²).
    pub area: f64,
    /// Admissible lower bound on internal cycles
    /// ([`FunctionalModel::cycle_lower_bound`]).
    pub cycles_lb: u64,
    /// Admissible upper bound on internal cycles
    /// ([`FunctionalModel::cycle_upper_bound`]).
    pub cycles_ub: u64,
    /// Best-case average power (W): exact event counts over the cycle
    /// upper bound (power falls as the same events spread over more
    /// time).
    pub power_lb: f64,
    /// Worst-case average power (W): exact event counts over the cycle
    /// lower bound.
    pub power_ub: f64,
    /// **Exact** off-chip words fetched
    /// ([`FunctionalModel::expected_offchip_reads`]) — the joint sweep's
    /// traffic axis, a closed-form event count with no interval at all.
    pub offchip_reads: u64,
}

/// A candidate dropped by the analytical prescreen — returned
/// bound-scored and flagged, never silently vanished.
#[derive(Debug, Clone)]
pub struct PrunedPoint {
    /// The pruned configuration.
    pub config: HierarchyConfig,
    /// Its analytical score at prune time.
    pub score: BoundScore,
    /// The mapping of a joint *(mapping, config)* candidate (`None` on
    /// config-only sweeps).
    pub mapping: Option<Mapping>,
}

/// Work accounting of a bound-and-prune sweep.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PruneStats {
    /// Candidates the streaming enumeration produced.
    pub enumerated: usize,
    /// Candidates dropped analytically (never simulated).
    pub bound_pruned: usize,
    /// Candidates forwarded to the cycle-accurate path.
    pub simulated: usize,
    /// Candidates whose program fails to compile (the exact paths skip
    /// these too, so dropping them early changes nothing).
    pub skipped: usize,
    /// Lower bound on the simulated cycles the prunes avoided: each
    /// pruned candidate would have cost at least its cycle lower bound.
    pub cycles_saved_lb: u64,
}

/// Compute a candidate's analytical score.
pub(crate) fn bound_score(
    cfg: &HierarchyConfig,
    fm: &FunctionalModel,
    eval_hz: f64,
) -> BoundScore {
    let area = hierarchy_area(cfg).total;
    let cycles_lb = fm.cycle_lower_bound();
    let cycles_ub = fm.cycle_upper_bound();
    let power_ub = run_power(cfg, &fm.activity_stats(cycles_lb), eval_hz).total;
    let power_lb = run_power(cfg, &fm.activity_stats(cycles_ub), eval_hz).total;
    BoundScore {
        area,
        cycles_lb,
        cycles_ub,
        power_lb,
        power_ub,
        offchip_reads: fm.expected_offchip_reads(),
    }
}

/// Equivalence-class key: two candidates with equal keys **and** equal
/// compiled programs simulate bit-identically (mechanism 2). Per level
/// the key keeps kind/geometry exactly, except that a standard level the
/// fetch stream never wraps (`total_writes <= capacity`) gets a
/// capacity-independent marker — the whole point: such levels behave
/// identically at any sufficient depth.
///
/// The key carries **no workload identity**: on a joint sweep, two
/// candidates under *different mappings* whose derived workloads compile
/// to the same [`McuProgram`] land in the same class and share one
/// simulation — the simulator consumes only the compiled program and the
/// behavior the key fixes, so the runs are bit-identical across
/// mappings too.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub(crate) struct BehaviorKey {
    /// (data_width, addr_width, latency, external_hz, internal_hz,
    /// ib_depth).
    offchip: (u32, u32, u64, u64, u64, u32),
    preload: bool,
    osr: Option<(u32, Vec<u32>)>,
    /// Per level: (double_buffered, banks, port count, word_width,
    /// capacity marker — `u64::MAX` for a never-wrapping standard level,
    /// else the exact capacity).
    levels: Vec<(bool, u32, u32, u32, u64)>,
}

fn behavior_key(cfg: &HierarchyConfig, prog: &McuProgram) -> BehaviorKey {
    let levels = cfg
        .levels
        .iter()
        .zip(prog.levels.iter())
        .map(|(l, u)| {
            let (db, banks, ports) = match l.kind {
                LevelKind::Standard { banks, ports } => (false, banks, ports.count()),
                LevelKind::DoubleBuffered => (true, 0, 0),
            };
            let cap = l.capacity_words();
            let marker = if !db && u.total_writes <= cap { u64::MAX } else { cap };
            (db, banks, ports, l.word_width, marker)
        })
        .collect();
    BehaviorKey {
        offchip: (
            cfg.offchip.data_width,
            cfg.offchip.addr_width,
            cfg.offchip.latency,
            cfg.offchip.external_hz,
            cfg.offchip.internal_hz,
            cfg.offchip.ib_depth,
        ),
        preload: cfg.preload,
        osr: cfg.osr.as_ref().map(|o| (o.width, o.shifts.clone())),
        levels,
    }
}

/// One retained equivalence-class member: the exact quantities on which
/// same-behavior candidates still differ.
struct ClassRep {
    /// Exact area.
    area: f64,
    /// Per-level (leakage, access energy): the only power coefficients
    /// that vary inside a class (every other `run_power` term depends on
    /// widths and counts the key already fixes).
    coeffs: Vec<(f64, f64)>,
    /// The compiled program; equality is the final word on bit-identical
    /// simulation.
    prog: McuProgram,
}

/// Whether class member `m` dominates a same-class candidate with the
/// given exact area and power coefficients: strictly smaller area and
/// componentwise no-worse power coefficients mean `m`'s true point beats
/// the candidate's (equal cycles, power no higher, area strictly lower).
fn class_dominates(m: &ClassRep, area: f64, coeffs: &[(f64, f64)]) -> bool {
    m.area < area
        && m.coeffs.len() == coeffs.len()
        && m.coeffs.iter().zip(coeffs).all(|(a, b)| a.0 <= b.0 && a.1 <= b.1)
}

/// A pass-one survivor awaiting the pass-two re-filter.
struct Pending {
    index: usize,
    widx: usize,
    mapping: Option<Mapping>,
    cfg: HierarchyConfig,
    score: BoundScore,
    key: BehaviorKey,
    coeffs: Vec<(f64, f64)>,
    prog: McuProgram,
}

/// A prescreen survivor with everything the memoized joint explorer
/// needs: its enumeration position, workload index, and behavioral
/// identity (key + compiled program) for class grouping.
pub(crate) struct Survivor {
    /// Global enumeration index.
    pub(crate) index: usize,
    /// Workload (= mapping) index the candidate is scored on.
    pub(crate) widx: usize,
    /// The configuration.
    pub(crate) cfg: HierarchyConfig,
    /// Behavioral-class key.
    pub(crate) key: BehaviorKey,
    /// The compiled program — equality is the final word on
    /// bit-identical simulation within a key.
    pub(crate) prog: McuProgram,
}

/// Result of a config-only [`Prescreen`] run over an enumeration.
pub(crate) struct PrescreenOutcome {
    /// Candidates to forward to the cycle-accurate path, in enumeration
    /// order.
    pub(crate) survivors: Vec<HierarchyConfig>,
    /// Candidates dropped analytically, bound-scored, in enumeration
    /// order.
    pub(crate) pruned: Vec<PrunedPoint>,
    /// Work accounting.
    pub(crate) stats: PruneStats,
}

/// Result of a joint prescreen: survivors keep their behavioral identity
/// so the explorer can memoize simulations class-wide.
pub(crate) struct JointPrescreenOutcome {
    /// Survivors in enumeration order.
    pub(crate) survivors: Vec<Survivor>,
    /// Candidates dropped analytically, mapping-tagged, in enumeration
    /// order.
    pub(crate) pruned: Vec<PrunedPoint>,
    /// Work accounting.
    pub(crate) stats: PruneStats,
}

/// Streaming two-pass analytical prescreen (see the module docs).
/// Feed candidates in enumeration order via [`Prescreen::observe`], then
/// [`Prescreen::finish`]. With `traffic_axis` set the frontier trades on
/// (area, cycles, power, off-chip reads) — the traffic component is an
/// exact event count, so it enters both the witness's worst case and the
/// queried candidate's best case at the same value.
pub(crate) struct Prescreen {
    eval_hz: f64,
    traffic_axis: bool,
    frontier: BoundFrontier,
    classes: BTreeMap<BehaviorKey, Vec<ClassRep>>,
    live: Vec<Pending>,
    pruned: Vec<(usize, PrunedPoint)>,
    stats: PruneStats,
}

impl Prescreen {
    pub(crate) fn new(eval_hz: f64, traffic_axis: bool) -> Self {
        Self {
            eval_hz,
            traffic_axis,
            frontier: BoundFrontier::new(),
            classes: BTreeMap::new(),
            live: Vec::new(),
            pruned: Vec::new(),
            stats: PruneStats::default(),
        }
    }

    /// The frontier's auxiliary-axis vector for a candidate: power alone,
    /// or (power, traffic) when the traffic axis is on.
    fn aux(&self, power: f64, offchip_reads: u64) -> Vec<f64> {
        if self.traffic_axis {
            vec![power, offchip_reads as f64]
        } else {
            vec![power]
        }
    }

    /// Pass one: score `cfg` against `workload`, prune on arrival if
    /// already provably dominated, and record it as a witness either way.
    /// `widx`/`mapping` tag the candidate's position in a joint space
    /// (`0`/`None` on config-only sweeps).
    pub(crate) fn observe(
        &mut self,
        cfg: HierarchyConfig,
        workload: &PatternProgram,
        widx: usize,
        mapping: Option<Mapping>,
    ) {
        let index = self.stats.enumerated;
        self.stats.enumerated += 1;
        // A compile failure here fails `load_program` in the exact paths
        // too: same skip, decided without building a hierarchy.
        let Ok(fm) = FunctionalModel::new(&cfg, workload) else {
            self.stats.skipped += 1;
            return;
        };
        let score = bound_score(&cfg, &fm, self.eval_hz);
        let key = behavior_key(&cfg, fm.compiled());
        let coeffs: Vec<(f64, f64)> =
            cfg.levels.iter().map(|l| (level_leakage(l), level_access_energy(l))).collect();
        let class = self.classes.entry(key.clone()).or_default();
        let class_doomed = class
            .iter()
            .any(|m| m.prog == *fm.compiled() && class_dominates(m, score.area, &coeffs));
        if !class_doomed {
            // Class-dominated candidates need no rep entry: whatever they
            // could dominate, their (transitive) dominator dominates too.
            class.push(ClassRep {
                area: score.area,
                coeffs: coeffs.clone(),
                prog: fm.compiled().clone(),
            });
        }
        let doomed = class_doomed
            || self.frontier.dominated(
                score.area,
                score.cycles_lb,
                &self.aux(score.power_lb, score.offchip_reads),
            );
        // Every valid candidate is a frontier witness, pruned or not: its
        // worst case is real and its true point appears in the exhaustive
        // sweep either way.
        self.frontier.insert(
            score.area,
            score.cycles_ub,
            &self.aux(score.power_ub, score.offchip_reads),
        );
        if doomed {
            self.pruned.push((index, PrunedPoint { config: cfg, score, mapping }));
        } else {
            self.live.push(Pending {
                index,
                widx,
                mapping,
                cfg,
                score,
                key,
                coeffs,
                prog: fm.compiled().clone(),
            });
        }
    }

    /// Pass two: re-filter the pass-one survivors against the final
    /// frontier and classes, so the verdict is independent of emission
    /// order.
    pub(crate) fn finish(mut self) -> JointPrescreenOutcome {
        let mut survivors = Vec::new();
        for p in self.live {
            let class_doomed = self
                .classes
                .get(&p.key)
                .is_some_and(|class| {
                    class
                        .iter()
                        .any(|m| m.prog == p.prog && class_dominates(m, p.score.area, &p.coeffs))
                });
            let doomed = class_doomed
                || self.frontier.dominated(
                    p.score.area,
                    p.score.cycles_lb,
                    &self.aux(p.score.power_lb, p.score.offchip_reads),
                );
            if doomed {
                self.pruned.push((
                    p.index,
                    PrunedPoint { config: p.cfg, score: p.score, mapping: p.mapping },
                ));
            } else {
                survivors.push(Survivor {
                    index: p.index,
                    widx: p.widx,
                    cfg: p.cfg,
                    key: p.key,
                    prog: p.prog,
                });
            }
        }
        self.pruned.sort_by_key(|&(i, _)| i);
        self.stats.bound_pruned = self.pruned.len();
        self.stats.simulated = survivors.len();
        self.stats.cycles_saved_lb = self.pruned.iter().map(|(_, p)| p.score.cycles_lb).sum();
        JointPrescreenOutcome {
            survivors,
            pruned: self.pruned.into_iter().map(|(_, p)| p).collect(),
            stats: self.stats,
        }
    }
}

/// Run the analytical prescreen over a space's streaming enumeration
/// (config-only: three frontier axes, one workload).
pub(crate) fn prescreen(space: &SearchSpace, workload: &PatternProgram) -> PrescreenOutcome {
    let mut ps = Prescreen::new(space.eval_hz, false);
    for cfg in space.candidates() {
        ps.observe(cfg, workload, 0, None);
    }
    let out = ps.finish();
    PrescreenOutcome {
        survivors: out.survivors.into_iter().map(|s| s.cfg).collect(),
        pruned: out.pruned,
        stats: out.stats,
    }
}

/// Run the analytical prescreen over a joint space's streaming
/// enumeration: four frontier axes (traffic exact), witnesses drawn from
/// **all** mappings (sound — every candidate of the sweep competes on
/// the same four objectives), and behavioral classes spanning mappings.
pub(crate) fn joint_prescreen(joint: &JointSpace) -> JointPrescreenOutcome {
    let mut ps = Prescreen::new(joint.space.eval_hz, true);
    for (wi, cfg) in joint.candidates() {
        ps.observe(cfg, &joint.workloads[wi], wi, Some(joint.mappings[wi]));
    }
    ps.finish()
}

#[cfg(test)]
mod tests {
    use super::super::search::KindChoice;
    use super::*;
    use crate::mem::Hierarchy;

    fn simulate_cycles(cfg: &HierarchyConfig, prog: &PatternProgram) -> u64 {
        let mut h = Hierarchy::new(cfg).unwrap();
        h.load_program(prog).unwrap();
        h.run().unwrap().stats.internal_cycles
    }

    /// The scores the pruner trades on must bracket the truth.
    #[test]
    fn bound_score_brackets_simulation() {
        let cfg = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level(32, 128, 1, 2)
            .build()
            .unwrap();
        let prog = PatternProgram::cyclic(0, 64).with_outputs(640);
        let fm = FunctionalModel::new(&cfg, &prog).unwrap();
        let s = bound_score(&cfg, &fm, 100e6);
        let cycles = simulate_cycles(&cfg, &prog);
        assert!(s.cycles_lb <= cycles && cycles <= s.cycles_ub, "{s:?} vs {cycles}");
        assert!(s.power_lb <= s.power_ub);
        assert!(s.area > 0.0);
        // The traffic axis has no interval: it is the exact event count.
        assert_eq!(s.offchip_reads, fm.expected_offchip_reads());
    }

    /// Mechanism 2's premise, end to end: candidates differing only in a
    /// never-wrapping standard level's depth share a key, share a
    /// program, and simulate to the same cycle count.
    #[test]
    fn equivalent_depths_share_key_and_cycles() {
        let prog = PatternProgram::cyclic(0, 48).with_outputs(480);
        let mk = |d0: u64| {
            HierarchyConfig::builder()
                .offchip(32, 24, 1.0)
                .level(32, d0, 1, 1)
                .level(32, 64, 1, 1)
                .build()
                .unwrap()
        };
        let (a, b) = (mk(128), mk(256));
        let fa = FunctionalModel::new(&a, &prog).unwrap();
        let fb = FunctionalModel::new(&b, &prog).unwrap();
        assert_eq!(behavior_key(&a, fa.compiled()), behavior_key(&b, fb.compiled()));
        assert_eq!(fa.compiled(), fb.compiled());
        assert_eq!(simulate_cycles(&a, &prog), simulate_cycles(&b, &prog));
    }

    /// And the guard: a level the stream *does* wrap keeps its exact
    /// capacity in the key, so different depths stay in different
    /// classes.
    #[test]
    fn wrapping_depths_get_distinct_keys() {
        let prog = PatternProgram::cyclic(0, 256).with_outputs(1_024);
        let mk = |d: u64| {
            HierarchyConfig::builder()
                .offchip(32, 24, 1.0)
                .level(32, d, 1, 1)
                .build()
                .unwrap()
        };
        let (a, b) = (mk(32), mk(64));
        let fa = FunctionalModel::new(&a, &prog).unwrap();
        let fb = FunctionalModel::new(&b, &prog).unwrap();
        assert_ne!(behavior_key(&a, fa.compiled()), behavior_key(&b, fb.compiled()));
    }

    /// The prescreen's ledger always balances, and an all-fitting space
    /// (many equivalent depths) prunes most of its candidates.
    #[test]
    fn prescreen_accounts_every_candidate() {
        let space = SearchSpace {
            depths: vec![1, 2],
            ram_depths: vec![64, 128, 256, 512],
            word_widths: vec![32],
            level_kinds: vec![KindChoice::Standard],
            try_dual_ported: false,
            protections: vec![crate::config::Protection::None],
            eval_hz: 100e6,
        };
        let w = PatternProgram::cyclic(0, 48).with_outputs(480);
        let out = prescreen(&space, &w);
        assert_eq!(
            out.stats.enumerated,
            out.stats.bound_pruned + out.stats.simulated + out.stats.skipped,
            "{:?}",
            out.stats
        );
        assert_eq!(out.survivors.len(), out.stats.simulated);
        assert_eq!(out.pruned.len(), out.stats.bound_pruned);
        assert!(out.stats.bound_pruned > 0, "equivalent depths must collapse: {:?}", out.stats);
        assert!(out.stats.cycles_saved_lb > 0);
    }

    /// The joint prescreen's ledger balances over the full (mapping ×
    /// config) enumeration, survivors keep enumeration order, and every
    /// prune is mapping-tagged.
    #[test]
    fn joint_prescreen_accounts_every_candidate() {
        use super::super::dims::JointSpace;
        use crate::loopnest::LoopOrder;
        use crate::model::{LayerKind, LayerSpec};
        let space = SearchSpace {
            depths: vec![1, 2],
            ram_depths: vec![64, 128, 256],
            word_widths: vec![32],
            level_kinds: vec![KindChoice::Standard],
            try_dual_ported: false,
            protections: vec![crate::config::Protection::None],
            eval_hz: 100e6,
        };
        let layer = LayerSpec { idx: 0, kind: LayerKind::Conv, k: 16, c: 8, f: 3, x: 4 };
        let joint = JointSpace::new(
            space,
            layer,
            16,
            &[LoopOrder::ultratrail(), LoopOrder::output_stationary()],
        );
        let out = joint_prescreen(&joint);
        assert_eq!(out.stats.enumerated, joint.candidates().count());
        assert_eq!(
            out.stats.enumerated,
            out.stats.bound_pruned + out.stats.simulated + out.stats.skipped,
            "{:?}",
            out.stats
        );
        assert_eq!(out.survivors.len(), out.stats.simulated);
        assert!(out.survivors.windows(2).all(|w| w[0].index < w[1].index));
        assert!(out.pruned.iter().all(|p| p.mapping.is_some()));
        assert!(out.survivors.iter().all(|s| s.widx < joint.mappings.len()));
    }
}

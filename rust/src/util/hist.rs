//! Streaming log-linear histogram for latency/cycle distributions.
//!
//! The serving tier ([`crate::coordinator`]) needs p50/p95/p99 over
//! millions of samples without storing them. [`StreamingHistogram`] uses
//! HDR-style log-linear buckets: values below [`LINEAR_CUTOFF`] are exact,
//! larger values land in one of [`SUB_BUCKETS`] linear sub-buckets per
//! power of two, bounding the relative quantile error at
//! `1/SUB_BUCKETS` (6.25 %). Recording is O(1), quantiles are O(buckets),
//! and the whole structure is deterministic: the same sample sequence
//! yields bit-identical counts and quantiles on any platform — which is
//! what lets `CoordinatorStats` assert reproducibility under a seeded
//! request trace.

use std::time::Duration;

/// Values below this record exactly (one bucket per value).
const LINEAR_CUTOFF: u64 = 64;
/// Linear sub-buckets per power-of-two range above the cutoff.
const SUB_BUCKETS: usize = 16;
/// log2(LINEAR_CUTOFF): first sub-bucketed power.
const CUTOFF_BITS: u32 = 6;
/// Total buckets: 64 exact + 16 per power of two for bits 6..=63.
const BUCKETS: usize = LINEAR_CUTOFF as usize + (64 - CUTOFF_BITS as usize) * SUB_BUCKETS;

/// A fixed-memory streaming histogram over `u64` samples (see module
/// docs). `Default` is an empty histogram; bucket storage is allocated
/// lazily on the first [`StreamingHistogram::record`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StreamingHistogram {
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
}

/// Bucket index of a value.
#[inline]
fn bucket_of(v: u64) -> usize {
    if v < LINEAR_CUTOFF {
        return v as usize;
    }
    let bits = 63 - v.leading_zeros(); // >= CUTOFF_BITS
    let sub = ((v >> (bits - 4)) & 0xF) as usize; // top 4 bits after the leader
    LINEAR_CUTOFF as usize + (bits - CUTOFF_BITS) as usize * SUB_BUCKETS + sub
}

/// Lower bound of a bucket (the value reported for quantiles in it).
#[inline]
fn bucket_floor(i: usize) -> u64 {
    if i < LINEAR_CUTOFF as usize {
        return i as u64;
    }
    let rel = i - LINEAR_CUTOFF as usize;
    let bits = CUTOFF_BITS + (rel / SUB_BUCKETS) as u32;
    let sub = (rel % SUB_BUCKETS) as u64;
    (1u64 << bits) + (sub << (bits - 4))
}

impl StreamingHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample.
    pub fn record(&mut self, v: u64) {
        if self.counts.is_empty() {
            self.counts = vec![0; BUCKETS];
            self.min = v;
            self.max = v;
        }
        self.counts[bucket_of(v)] += 1;
        self.total += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Record a [`Duration`] in nanoseconds (saturating at `u64::MAX`).
    pub fn record_duration(&mut self, d: Duration) {
        self.record(u64::try_from(d.as_nanos()).unwrap_or(u64::MAX));
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// True if nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Mean of all samples (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.sum as f64 / self.total as f64
    }

    /// Smallest recorded sample (0 when empty).
    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    /// Largest recorded sample (0 when empty).
    pub fn max(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.max
        }
    }

    /// The `q`-quantile (`0.0 ..= 1.0`): the lower bound of the bucket
    /// holding the sample of rank `ceil(q * count)`, clamped to the
    /// observed min/max so `quantile(0.0)`/`quantile(1.0)` are exact.
    /// Returns 0 on an empty histogram.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_floor(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Median.
    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    /// 95th percentile.
    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }

    /// 99th percentile.
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Fold another histogram into this one.
    pub fn merge(&mut self, other: &Self) {
        if other.total == 0 {
            return;
        }
        if self.counts.is_empty() {
            self.counts = vec![0; BUCKETS];
            self.min = other.min;
            self.max = other.max;
        }
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values_are_exact() {
        let mut h = StreamingHistogram::new();
        for v in 0..LINEAR_CUTOFF {
            h.record(v);
        }
        assert_eq!(h.count(), LINEAR_CUTOFF);
        assert_eq!(h.quantile(0.5), 32);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), LINEAR_CUTOFF - 1);
        assert_eq!(h.quantile(1.0), LINEAR_CUTOFF - 1);
    }

    #[test]
    fn quantile_error_bounded_above_cutoff() {
        // Uniform samples over a wide range: every reported quantile must
        // sit within one sub-bucket (6.25 %) below the exact value.
        let mut h = StreamingHistogram::new();
        let mut exact = Vec::new();
        let mut x = 0x243F_6A88_85A3_08D3u64; // deterministic LCG
        for _ in 0..100_000 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let v = x >> 34; // ~2^30 range
            exact.push(v);
            h.record(v);
        }
        exact.sort_unstable();
        for q in [0.5, 0.95, 0.99] {
            let rank = ((q * exact.len() as f64).ceil() as usize).max(1) - 1;
            let truth = exact[rank] as f64;
            let got = h.quantile(q) as f64;
            assert!(got <= truth, "q{q}: histogram {got} above exact {truth}");
            let floor = truth * (1.0 - 1.0 / SUB_BUCKETS as f64) - 1.0;
            assert!(got >= floor, "q{q}: {got} vs {truth}");
        }
    }

    #[test]
    fn bucket_floor_inverts_bucket_of() {
        // The floor of a value's bucket never exceeds the value, and the
        // next bucket's floor is strictly above it.
        for v in [0u64, 1, 63, 64, 65, 100, 1 << 20, u64::MAX / 2, u64::MAX] {
            let b = bucket_of(v);
            assert!(bucket_floor(b) <= v, "floor({b}) > {v}");
            if b + 1 < BUCKETS {
                assert!(bucket_floor(b + 1) > v, "next floor not above {v}");
            }
        }
    }

    #[test]
    fn deterministic_and_mergeable() {
        let feed = |h: &mut StreamingHistogram, seed: u64| {
            for i in 0..5_000u64 {
                h.record(seed.wrapping_mul(i) % 100_000);
            }
        };
        let (mut a, mut b) = (StreamingHistogram::new(), StreamingHistogram::new());
        feed(&mut a, 7);
        feed(&mut b, 7);
        assert_eq!(a, b, "same samples must yield identical histograms");
        let mut c = StreamingHistogram::new();
        feed(&mut c, 7);
        feed(&mut c, 13);
        let mut d = StreamingHistogram::new();
        feed(&mut d, 13);
        a.merge(&d);
        assert_eq!(a, c, "merge must equal recording both streams");
        // Merge into empty adopts the source.
        let mut e = StreamingHistogram::new();
        e.merge(&c);
        assert_eq!(e, c);
    }

    #[test]
    fn empty_histogram_is_quiet() {
        let h = StreamingHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.99), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert!(h.is_empty());
    }

    #[test]
    fn duration_recording_uses_nanos() {
        let mut h = StreamingHistogram::new();
        h.record_duration(Duration::from_micros(5));
        assert_eq!(h.max(), 5_000);
    }
}

//! Minimal CLI argument parser (offline substitute for `clap`).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, and
//! positional arguments, with generated `--help` text.

use std::collections::BTreeMap;

/// Declarative description of one option.
#[derive(Debug, Clone)]
pub struct OptSpec {
    /// Long name without `--`.
    pub name: &'static str,
    /// Help text.
    pub help: &'static str,
    /// Whether the option takes a value (`--k v`) or is a boolean flag.
    pub takes_value: bool,
    /// Default value (shown in help, used when absent).
    pub default: Option<&'static str>,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default, Clone)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Positional arguments in order.
    pub positional: Vec<String>,
}

impl Args {
    /// Value of `--name`, falling back to the spec default.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Parse `--name` as `T`, with a default.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String> {
        match self.values.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| format!("invalid value for --{name}: {s:?}")),
        }
    }

    /// Whether the boolean `--name` flag was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// A subcommand with its options.
#[derive(Debug, Clone)]
pub struct Command {
    /// Subcommand name.
    pub name: &'static str,
    /// One-line description.
    pub about: &'static str,
    /// Option specs.
    pub opts: Vec<OptSpec>,
}

/// Top-level CLI: a set of subcommands.
pub struct Cli {
    /// Binary name for help output.
    pub bin: &'static str,
    /// One-line program description.
    pub about: &'static str,
    /// Subcommands.
    pub commands: Vec<Command>,
}

impl Cli {
    /// Render the global help text.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <command> [options]\n\nCOMMANDS:\n", self.bin, self.about, self.bin);
        for c in &self.commands {
            s.push_str(&format!("  {:<18} {}\n", c.name, c.about));
        }
        s.push_str("\nRun `");
        s.push_str(self.bin);
        s.push_str(" <command> --help` for command options.\n");
        s
    }

    /// Render help for one subcommand.
    pub fn command_help(&self, cmd: &Command) -> String {
        let mut s = format!("{} {} — {}\n\nOPTIONS:\n", self.bin, cmd.name, cmd.about);
        for o in &cmd.opts {
            let lhs = if o.takes_value {
                format!("--{} <v>", o.name)
            } else {
                format!("--{}", o.name)
            };
            let dflt = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  {:<24} {}{}\n", lhs, o.help, dflt));
        }
        s
    }

    /// Parse `argv[1..]`. Returns `(command_name, args)` or an error/help
    /// message the caller should print.
    pub fn parse(&self, argv: &[String]) -> Result<(String, Args), String> {
        if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" || argv[0] == "help" {
            return Err(self.help());
        }
        let cmd_name = &argv[0];
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| format!("unknown command {cmd_name:?}\n\n{}", self.help()))?;

        let mut args = Args::default();
        // Seed defaults.
        for o in &cmd.opts {
            if let (true, Some(d)) = (o.takes_value, o.default) {
                args.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.command_help(cmd));
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (name, inline_val) = match rest.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = cmd
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name} for {cmd_name}\n\n{}", self.command_help(cmd)))?;
                if spec.takes_value {
                    let v = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} requires a value"))?
                        }
                    };
                    args.values.insert(name, v);
                } else {
                    if inline_val.is_some() {
                        return Err(format!("--{name} does not take a value"));
                    }
                    args.flags.push(name);
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok((cmd_name.clone(), args))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli {
            bin: "memhier",
            about: "test",
            commands: vec![Command {
                name: "simulate",
                about: "run a simulation",
                opts: vec![
                    OptSpec { name: "cycle-length", help: "", takes_value: true, default: Some("64") },
                    OptSpec { name: "preload", help: "", takes_value: false, default: None },
                    OptSpec { name: "out", help: "", takes_value: true, default: None },
                ],
            }],
        }
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_values_flags_positionals() {
        let (cmd, a) = cli()
            .parse(&sv(&["simulate", "--cycle-length", "128", "--preload", "trace.csv"]))
            .unwrap();
        assert_eq!(cmd, "simulate");
        assert_eq!(a.get_parse("cycle-length", 0u64).unwrap(), 128);
        assert!(a.flag("preload"));
        assert_eq!(a.positional, vec!["trace.csv"]);
    }

    #[test]
    fn equals_syntax_and_defaults() {
        let (_, a) = cli().parse(&sv(&["simulate", "--cycle-length=256"])).unwrap();
        assert_eq!(a.get("cycle-length"), Some("256"));
        let (_, a) = cli().parse(&sv(&["simulate"])).unwrap();
        assert_eq!(a.get("cycle-length"), Some("64"), "default applies");
        assert!(!a.flag("preload"));
    }

    #[test]
    fn unknown_command_and_option_error() {
        assert!(cli().parse(&sv(&["nope"])).is_err());
        assert!(cli().parse(&sv(&["simulate", "--bogus"])).is_err());
        assert!(cli().parse(&sv(&["simulate", "--out"])).is_err(), "missing value");
    }

    #[test]
    fn help_requested() {
        let err = cli().parse(&sv(&["--help"])).unwrap_err();
        assert!(err.contains("COMMANDS"));
        let err = cli().parse(&sv(&["simulate", "--help"])).unwrap_err();
        assert!(err.contains("--cycle-length"));
    }
}

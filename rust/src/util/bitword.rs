//! Wide bit-words (up to 512 bits) for modelling data words flowing through
//! the hierarchy: off-chip words (e.g. 32-bit), level words (up to
//! 128-bit), and OSR contents (the UltraTrail case study needs a 384-bit
//! weight port = 64 MACs × 6-bit weights).
//!
//! Data integrity through the hierarchy is one of the paper's correctness
//! claims (§4.1.3), so the simulator carries real payloads, not just
//! address tags: the input buffer concatenates narrow off-chip words into
//! wide level-0 words exactly like the RTL register file would, and the OSR
//! performs real shifts.

use crate::util::frame::{ByteReader, ByteWriter};
use crate::{Error, Result};
use std::fmt;

/// Maximum supported word width in bits.
pub const MAX_WIDTH: u32 = 512;
const LIMBS: usize = (MAX_WIDTH as usize) / 64;

/// A little-endian fixed-capacity bit vector: bit 0 is the LSB of limb 0.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Word {
    limbs: [u64; LIMBS],
    width: u32,
}

impl Word {
    /// All-zero word of `width` bits.
    pub fn zero(width: u32) -> Self {
        assert!(width <= MAX_WIDTH, "word width {width} > {MAX_WIDTH}");
        Self { limbs: [0; LIMBS], width }
    }

    /// Word of `width` bits from the low bits of `v`.
    pub fn from_u64(v: u64, width: u32) -> Self {
        let mut w = Self::zero(width);
        w.limbs[0] = if width >= 64 { v } else { v & Self::mask64(width) };
        w
    }

    /// Word of `width` bits from the low bits of `v`.
    pub fn from_u128(v: u128, width: u32) -> Self {
        let mut w = Self::zero(width);
        w.limbs[0] = v as u64;
        w.limbs[1] = (v >> 64) as u64;
        w.truncate_to_width();
        w
    }

    fn mask64(bits: u32) -> u64 {
        if bits >= 64 {
            u64::MAX
        } else {
            (1u64 << bits) - 1
        }
    }

    fn truncate_to_width(&mut self) {
        let full = (self.width / 64) as usize;
        let rem = self.width % 64;
        for i in full + 1..LIMBS {
            self.limbs[i] = 0;
        }
        if (full as usize) < LIMBS {
            if rem == 0 {
                self.limbs[full] = 0;
            } else {
                self.limbs[full] &= Self::mask64(rem);
            }
        }
    }

    /// Width in bits.
    pub fn width(&self) -> u32 {
        self.width
    }

    /// Low 64 bits.
    pub fn as_u64(&self) -> u64 {
        self.limbs[0]
    }

    /// Low 128 bits.
    pub fn as_u128(&self) -> u128 {
        (self.limbs[0] as u128) | ((self.limbs[1] as u128) << 64)
    }

    /// Extract `len` bits starting at bit `lo` (little-endian bit order).
    pub fn bits(&self, lo: u32, len: u32) -> Word {
        assert!(lo + len <= self.width, "bit slice [{lo}, {}) out of width {}", lo + len, self.width);
        let mut out = Word::zero(len);
        // Fast path: the slice lives within one limb (the common case —
        // 32-bit off-chip words inside 64-bit limbs).
        let limb = (lo / 64) as usize;
        let off = lo % 64;
        if off + len <= 64 {
            out.limbs[0] = (self.limbs[limb] >> off) & Self::mask64(len);
            return out;
        }
        // Limb-aligned wide slices: copy whole limbs.
        if off == 0 && len % 64 == 0 {
            let n = (len / 64) as usize;
            out.limbs[..n].copy_from_slice(&self.limbs[limb..limb + n]);
            return out;
        }
        for i in 0..len {
            let src = lo + i;
            let bit = (self.limbs[(src / 64) as usize] >> (src % 64)) & 1;
            out.limbs[(i / 64) as usize] |= bit << (i % 64);
        }
        out
    }

    /// Set `bits.width()` bits starting at bit `lo` from `bits`.
    pub fn set_bits(&mut self, lo: u32, bits: &Word) {
        assert!(lo + bits.width <= self.width, "set_bits out of range");
        let limb = (lo / 64) as usize;
        let off = lo % 64;
        // Fast path: destination within one limb.
        if off + bits.width <= 64 {
            let m = Self::mask64(bits.width) << off;
            self.limbs[limb] = (self.limbs[limb] & !m) | ((bits.limbs[0] << off) & m);
            return;
        }
        // Limb-aligned wide writes.
        if off == 0 && bits.width % 64 == 0 {
            let n = (bits.width / 64) as usize;
            self.limbs[limb..limb + n].copy_from_slice(&bits.limbs[..n]);
            return;
        }
        for i in 0..bits.width {
            let b = (bits.limbs[(i / 64) as usize] >> (i % 64)) & 1;
            let dst = lo + i;
            let l = &mut self.limbs[(dst / 64) as usize];
            let m = 1u64 << (dst % 64);
            if b == 1 {
                *l |= m;
            } else {
                *l &= !m;
            }
        }
    }

    /// Concatenate `self` (low bits) with `hi` (high bits) into a wider word.
    pub fn concat(&self, hi: &Word) -> Word {
        let mut out = Word::zero(self.width + hi.width);
        out.set_bits(0, self);
        out.set_bits(self.width, hi);
        out
    }

    /// Logical left shift by `n` bits (width preserved, bits shifted out
    /// are dropped) — the OSR's shift operation.
    pub fn shl(&self, n: u32) -> Word {
        let mut out = Word::zero(self.width);
        if n >= self.width {
            return out;
        }
        for i in 0..self.width - n {
            let b = (self.limbs[(i / 64) as usize] >> (i % 64)) & 1;
            out.limbs[((i + n) / 64) as usize] |= b << ((i + n) % 64);
        }
        out
    }

    /// The top `n` bits as a word of width `n` — what the OSR emits when
    /// shifting left by `n`.
    pub fn top_bits(&self, n: u32) -> Word {
        assert!(n <= self.width);
        self.bits(self.width - n, n)
    }

    /// Split into `count` equal chunks, LSB-first. Width must divide evenly.
    pub fn split(&self, count: u32) -> Vec<Word> {
        assert!(count > 0 && self.width % count == 0);
        let w = self.width / count;
        (0..count).map(|i| self.bits(i * w, w)).collect()
    }

    fn limbs_used(width: u32) -> usize {
        width.div_ceil(64) as usize
    }

    /// Serialize for the checkpoint wire format ([`crate::mem::wire`]):
    /// the width, then only the populated limbs (little-endian).
    pub(crate) fn wire_write(&self, w: &mut ByteWriter) {
        w.put_u32(self.width);
        for limb in &self.limbs[..Self::limbs_used(self.width)] {
            w.put_u64(*limb);
        }
    }

    /// Decode a word written by [`Self::wire_write`]. Checked: an
    /// out-of-range width is a parse error, and decoded bits are
    /// re-truncated to the width so the result is always canonical.
    pub(crate) fn wire_read(r: &mut ByteReader<'_>) -> Result<Self> {
        let width = r.get_u32()?;
        if width > MAX_WIDTH {
            return Err(Error::Parse(format!("wire: word width {width} > {MAX_WIDTH}")));
        }
        let mut word = Self::zero(width);
        for i in 0..Self::limbs_used(width) {
            word.limbs[i] = r.get_u64()?;
        }
        word.truncate_to_width();
        Ok(word)
    }
}

impl fmt::Debug for Word {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Word<{}>(0x", self.width)?;
        let limbs_used = ((self.width + 63) / 64) as usize;
        for i in (0..limbs_used.max(1)).rev() {
            if i == limbs_used.saturating_sub(1) {
                write!(f, "{:x}", self.limbs[i])?;
            } else {
                write!(f, "{:016x}", self.limbs[i])?;
            }
        }
        write!(f, ")")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_u64_masks_to_width() {
        let w = Word::from_u64(0xFFFF, 8);
        assert_eq!(w.as_u64(), 0xFF);
        assert_eq!(w.width(), 8);
    }

    #[test]
    fn concat_orders_low_then_high() {
        // Input-buffer semantics: first off-chip word occupies the low bits.
        let a = Word::from_u64(0xAB, 8);
        let b = Word::from_u64(0xCD, 8);
        let c = a.concat(&b);
        assert_eq!(c.width(), 16);
        assert_eq!(c.as_u64(), 0xCDAB);
    }

    #[test]
    fn bits_roundtrip() {
        let w = Word::from_u128(0x1234_5678_9ABC_DEF0_1122_3344_5566_7788, 128);
        assert_eq!(w.bits(0, 32).as_u64(), 0x5566_7788);
        assert_eq!(w.bits(96, 32).as_u64(), 0x1234_5678);
        let parts = w.split(4);
        assert_eq!(parts.len(), 4);
        assert_eq!(parts[0].as_u64(), 0x5566_7788);
        assert_eq!(parts[3].as_u64(), 0x1234_5678);
    }

    #[test]
    fn set_bits_overwrites_only_range() {
        let mut w = Word::from_u64(0xFFFF_FFFF, 32);
        w.set_bits(8, &Word::from_u64(0x00, 8));
        assert_eq!(w.as_u64(), 0xFFFF_00FF);
    }

    #[test]
    fn shl_and_top_bits_are_osr_semantics() {
        // 16-bit OSR containing 0xABCD; shifting left by 4 emits the top
        // nibble (0xA) and leaves 0xBCD0.
        let w = Word::from_u64(0xABCD, 16);
        assert_eq!(w.top_bits(4).as_u64(), 0xA);
        assert_eq!(w.shl(4).as_u64(), 0xBCD0);
        // Shift by the full width clears the register.
        assert_eq!(w.shl(16).as_u64(), 0);
    }

    #[test]
    fn wide_words_512() {
        let mut w = Word::zero(512);
        w.set_bits(500, &Word::from_u64(0xF, 4));
        assert_eq!(w.bits(500, 4).as_u64(), 0xF);
        assert_eq!(w.bits(0, 64).as_u64(), 0);
    }

    #[test]
    fn case_study_osr_width_384() {
        // Three 128-bit hierarchy words fill the 384-bit weight port.
        let a = Word::from_u128(1, 128);
        let b = Word::from_u128(2, 128);
        let c = Word::from_u128(3, 128);
        let osr = a.concat(&b).concat(&c);
        assert_eq!(osr.width(), 384);
        let parts = osr.split(3);
        assert_eq!(parts[0].as_u128(), 1);
        assert_eq!(parts[1].as_u128(), 2);
        assert_eq!(parts[2].as_u128(), 3);
    }

    #[test]
    #[should_panic]
    fn oversize_width_panics() {
        let _ = Word::zero(513);
    }
}

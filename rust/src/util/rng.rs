//! Deterministic PRNGs for workload generation and property testing.
//!
//! `SplitMix64` seeds `Xoshiro256**` (Blackman & Vigna). Both are
//! reproducible across platforms — every experiment in the repo is seeded,
//! so reported numbers regenerate exactly.

/// Minimal RNG interface used throughout the crate.
pub trait Rng {
    /// Next raw 64-bit value.
    fn next_u64(&mut self) -> u64;

    /// Uniform value in `[0, bound)`. Uses Lemire's multiply-shift
    /// rejection method to avoid modulo bias.
    fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "gen_range bound must be > 0");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `f64` in `[0, 1)`.
    fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw with probability `p`.
    fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates shuffle.
    fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

/// SplitMix64 — used to expand a single `u64` seed into stream state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }
}

impl Rng for SplitMix64 {
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the workhorse generator.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 so that low-entropy seeds still give good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }
}

impl Rng for Xoshiro256 {
    fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 (from the published algorithm).
        let mut r = SplitMix64::new(0);
        let a = r.next_u64();
        let b = r.next_u64();
        assert_ne!(a, b);
        // Determinism across instances.
        let mut r2 = SplitMix64::new(0);
        assert_eq!(r2.next_u64(), a);
        assert_eq!(r2.next_u64(), b);
    }

    #[test]
    fn xoshiro_determinism_and_spread() {
        let mut r = Xoshiro256::new(42);
        let xs: Vec<u64> = (0..1000).map(|_| r.next_u64()).collect();
        let mut r2 = Xoshiro256::new(42);
        let ys: Vec<u64> = (0..1000).map(|_| r2.next_u64()).collect();
        assert_eq!(xs, ys);
        // Values should not repeat in a short window.
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), xs.len());
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Xoshiro256::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit in 1000 draws");
    }

    #[test]
    fn gen_f64_unit_interval() {
        let mut r = Xoshiro256::new(9);
        for _ in 0..1000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffle changed order");
    }
}

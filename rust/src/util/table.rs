//! Aligned text tables and CSV emission for the report/bench binaries.

/// A simple column-aligned text table with a header row.
#[derive(Debug, Default, Clone)]
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    /// New table with the given header.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    /// Append a row (must match header arity).
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with padded columns and a separator under the header.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = h.chars().count();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    s.push_str("  ");
                }
                let pad = widths[i] - c.chars().count();
                s.push_str(c);
                s.push_str(&" ".repeat(pad));
            }
            s.trim_end().to_string()
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (RFC-4180-ish quoting for cells containing commas).
    pub fn to_csv(&self) -> String {
        let esc = |c: &String| -> String {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        };
        let mut out = self.header.iter().map(esc).collect::<Vec<_>>().join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with `prec` decimals, trimming needless noise.
pub fn fnum(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

/// Format a percentage delta like `-62.2%` / `+6.2%`.
pub fn fpct(v: f64) -> String {
    format!("{}{:.1}%", if v >= 0.0 { "+" } else { "" }, v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = TextTable::new(vec!["layer", "cycles"]);
        t.row(vec!["0", "1920"]).row(vec!["11", "4"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[0].starts_with("layer"));
        assert!(lines[1].chars().all(|c| c == '-'));
        assert_eq!(lines.len(), 4);
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn csv_quotes_commas() {
        let mut t = TextTable::new(vec!["a", "b"]);
        t.row(vec!["x,y", "plain"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\",plain"));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = TextTable::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn fpct_signs() {
        assert_eq!(fpct(-62.2), "-62.2%");
        assert_eq!(fpct(6.2), "+6.2%");
    }
}

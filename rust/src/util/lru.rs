//! Explicit least-recently-used ordering with O(log n) operations.
//!
//! The serving tier keeps two bounded per-tenant caches (the realized
//! cycle cache and the speculative warm-state store). Their original
//! eviction strategy was a full `min_by_key` scan per insert — O(n) per
//! eviction, O(n²) across a tenant churn burst. [`LruOrder`] replaces the
//! scan with a stamp-keyed [`BTreeMap`]: `touch`, `remove`, and
//! `pop_oldest` are each one or two tree operations, so a churn burst over
//! n tenants costs O(n log n) total. `benches/serve_traffic.rs` asserts
//! the scaling.

use std::collections::BTreeMap;

/// LRU recency order over keys of type `K` (see module docs). Stores only
/// the ordering; the cached values live in the owning map.
#[derive(Debug, Clone, Default)]
pub struct LruOrder<K: Ord + Copy> {
    /// stamp → key, ordered oldest-first. Stamps are unique.
    by_stamp: BTreeMap<u64, K>,
    /// key → its current stamp.
    stamp_of: BTreeMap<K, u64>,
    /// Monotonic stamp source.
    tick: u64,
}

impl<K: Ord + Copy> LruOrder<K> {
    /// Empty order.
    pub fn new() -> Self {
        Self { by_stamp: BTreeMap::new(), stamp_of: BTreeMap::new(), tick: 0 }
    }

    /// Mark `k` as most recently used (inserting it if absent). O(log n).
    pub fn touch(&mut self, k: K) {
        self.tick += 1;
        if let Some(old) = self.stamp_of.insert(k, self.tick) {
            self.by_stamp.remove(&old);
        }
        self.by_stamp.insert(self.tick, k);
    }

    /// Remove `k` from the order; returns whether it was present.
    pub fn remove(&mut self, k: &K) -> bool {
        match self.stamp_of.remove(k) {
            Some(stamp) => {
                self.by_stamp.remove(&stamp);
                true
            }
            None => false,
        }
    }

    /// Remove and return the least-recently-used key. O(log n).
    pub fn pop_oldest(&mut self) -> Option<K> {
        let (_, k) = self.by_stamp.pop_first()?;
        self.stamp_of.remove(&k);
        Some(k)
    }

    /// The least-recently-used key, without removing it.
    pub fn oldest(&self) -> Option<K> {
        self.by_stamp.first_key_value().map(|(_, &k)| k)
    }

    /// Whether `k` is tracked.
    pub fn contains(&self, k: &K) -> bool {
        self.stamp_of.contains_key(k)
    }

    /// Tracked key count.
    pub fn len(&self) -> usize {
        self.stamp_of.len()
    }

    /// True when no keys are tracked.
    pub fn is_empty(&self) -> bool {
        self.stamp_of.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eviction_order_follows_recency() {
        let mut lru = LruOrder::new();
        for k in [1u64, 2, 3] {
            lru.touch(k);
        }
        assert_eq!(lru.oldest(), Some(1));
        lru.touch(1); // 2 is now oldest
        assert_eq!(lru.pop_oldest(), Some(2));
        assert_eq!(lru.pop_oldest(), Some(3));
        assert_eq!(lru.pop_oldest(), Some(1));
        assert_eq!(lru.pop_oldest(), None);
        assert!(lru.is_empty());
    }

    #[test]
    fn remove_and_contains() {
        let mut lru = LruOrder::new();
        lru.touch(7u64);
        lru.touch(8);
        assert!(lru.contains(&7));
        assert!(lru.remove(&7));
        assert!(!lru.remove(&7), "double remove reports absence");
        assert!(!lru.contains(&7));
        assert_eq!(lru.len(), 1);
        assert_eq!(lru.oldest(), Some(8));
    }

    #[test]
    fn maps_stay_consistent_under_churn() {
        let mut lru = LruOrder::new();
        for i in 0..1_000u64 {
            lru.touch(i % 97);
            if i % 3 == 0 {
                lru.pop_oldest();
            }
            if i % 11 == 0 {
                lru.remove(&(i % 97));
            }
            assert_eq!(lru.by_stamp.len(), lru.stamp_of.len(), "index desync at {i}");
        }
        // Every stamp round-trips through both maps.
        for (stamp, k) in &lru.by_stamp {
            assert_eq!(lru.stamp_of.get(k), Some(stamp));
        }
    }
}

//! In-tree infrastructure: PRNGs, wide bit-words, CLI argument parsing,
//! and small text/table helpers.
//!
//! The build environment is offline, so the usual crates (`rand`, `clap`,
//! `prettytable`) are replaced by these minimal, well-tested substrates.

pub mod bitword;
pub mod cli;
pub mod rng;
pub mod table;

pub use bitword::Word;
pub use rng::{Rng, SplitMix64, Xoshiro256};

/// Integer ceiling division `a.div_ceil(b)` for `u64` (stable helper used
/// across the crate for cycle/width arithmetic).
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Round `a` up to the next multiple of `m`.
#[inline]
pub fn round_up(a: u64, m: u64) -> u64 {
    ceil_div(a, m) * m
}

/// Greatest common divisor (Euclid).
pub fn gcd(mut a: u64, mut b: u64) -> u64 {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

/// Least common multiple; saturates on overflow.
pub fn lcm(a: u64, b: u64) -> u64 {
    if a == 0 || b == 0 {
        return 0;
    }
    (a / gcd(a, b)).saturating_mul(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_exact_and_rounding() {
        assert_eq!(ceil_div(8, 4), 2);
        assert_eq!(ceil_div(9, 4), 3);
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 1), 1);
    }

    #[test]
    fn round_up_multiples() {
        assert_eq!(round_up(5, 4), 8);
        assert_eq!(round_up(8, 4), 8);
        assert_eq!(round_up(0, 4), 0);
    }

    #[test]
    fn gcd_lcm_basic() {
        assert_eq!(gcd(12, 18), 6);
        assert_eq!(gcd(7, 13), 1);
        assert_eq!(gcd(0, 5), 5);
        assert_eq!(lcm(4, 6), 12);
        assert_eq!(lcm(0, 6), 0);
        // Case-study clocks: 1 MHz external, 250 kHz internal -> ratio 4.
        assert_eq!(lcm(1_000_000, 250_000) / 250_000, 4);
    }
}

//! In-tree infrastructure: PRNGs, wide bit-words, CLI argument parsing,
//! streaming histograms, LRU ordering, and small text/table helpers.
//!
//! The build environment is offline, so the usual crates (`rand`, `clap`,
//! `prettytable`) are replaced by these minimal, well-tested substrates.

pub mod bitword;
pub mod cli;
pub mod frame;
pub mod hist;
pub mod lru;
pub mod rng;
pub mod table;

pub use bitword::Word;
pub use hist::StreamingHistogram;
pub use lru::LruOrder;
pub use rng::{Rng, SplitMix64, Xoshiro256};

/// Integer ceiling division `a.div_ceil(b)` for `u64` (stable helper used
/// across the crate for cycle/width arithmetic).
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Round `a` up to the next multiple of `m`.
#[inline]
pub fn round_up(a: u64, m: u64) -> u64 {
    ceil_div(a, m) * m
}

/// Greatest common divisor (Euclid).
pub fn gcd(mut a: u64, mut b: u64) -> u64 {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

/// Least common multiple; saturates on overflow.
pub fn lcm(a: u64, b: u64) -> u64 {
    if a == 0 || b == 0 {
        return 0;
    }
    (a / gcd(a, b)).saturating_mul(b)
}

/// Deterministic scatter/gather: evaluate `f(0..n)` on `threads` workers
/// (`0` = one per available core) and return the results in index order,
/// independent of thread scheduling. The shared backbone of
/// `dse::pool::HierarchyPool` and the case-study layer fan-out — `f` must
/// be a pure function of its index for the determinism guarantee to mean
/// anything.
pub fn par_map_indexed<R, F>(n: usize, threads: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    par_map_indexed_with(n, threads, || (), |_, i| f(i))
}

/// [`par_map_indexed`] with worker-local state: each worker calls `init`
/// once and threads the resulting value mutably through every index it
/// claims. This is what warm-reusable simulation sessions hang off: the
/// state is typically a `sim::batch::Session` (or a pool of hierarchies)
/// that is re-armed, not reallocated, between work items. Determinism
/// still requires `f` to produce the same result for an index regardless
/// of which worker (and with which prior session history) evaluates it —
/// the warm-vs-cold equivalence the `mem` re-arm paths guarantee.
pub fn par_map_indexed_with<S, R, I, F>(n: usize, threads: usize, init: I, f: F) -> Vec<R>
where
    R: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> R + Sync,
{
    let threads = if threads == 0 {
        std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1)
    } else {
        threads
    };
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        let mut state = init();
        return (0..n).map(|i| f(&mut state, i)).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let results = std::sync::Mutex::new(Vec::with_capacity(n));
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let mut state = init();
                let mut local = Vec::new();
                loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    local.push((i, f(&mut state, i)));
                }
                results.lock().expect("worker panicked holding lock").extend(local);
            });
        }
    });
    let mut indexed = results.into_inner().expect("worker panicked holding lock");
    indexed.sort_by_key(|&(i, _)| i);
    indexed.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_exact_and_rounding() {
        assert_eq!(ceil_div(8, 4), 2);
        assert_eq!(ceil_div(9, 4), 3);
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 1), 1);
    }

    #[test]
    fn round_up_multiples() {
        assert_eq!(round_up(5, 4), 8);
        assert_eq!(round_up(8, 4), 8);
        assert_eq!(round_up(0, 4), 0);
    }

    #[test]
    fn par_map_indexed_orders_and_covers() {
        for threads in [0usize, 1, 3, 8] {
            let out = par_map_indexed(25, threads, |i| i * i);
            assert_eq!(out, (0..25).map(|i| i * i).collect::<Vec<_>>(), "threads={threads}");
        }
        assert!(par_map_indexed(0, 4, |i| i).is_empty());
    }

    #[test]
    fn par_map_with_state_orders_and_covers() {
        // Worker-local state must not leak into results: each worker
        // counts how many items it handled, f returns i*i regardless.
        for threads in [0usize, 1, 3, 8] {
            let out = par_map_indexed_with(
                25,
                threads,
                || 0u64,
                |seen, i| {
                    *seen += 1;
                    i * i
                },
            );
            assert_eq!(out, (0..25).map(|i| i * i).collect::<Vec<_>>(), "threads={threads}");
        }
        assert!(par_map_indexed_with(0, 4, || (), |_, i| i).is_empty());
    }

    #[test]
    fn gcd_lcm_basic() {
        assert_eq!(gcd(12, 18), 6);
        assert_eq!(gcd(7, 13), 1);
        assert_eq!(gcd(0, 5), 5);
        assert_eq!(lcm(4, 6), 12);
        assert_eq!(lcm(0, 6), 0);
        // Case-study clocks: 1 MHz external, 250 kHz internal -> ratio 4.
        assert_eq!(lcm(1_000_000, 250_000) / 250_000, 4);
    }
}

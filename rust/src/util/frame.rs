//! Length-prefixed binary encoding and stream framing (offline substitute
//! for `byteorder`/`bincode`).
//!
//! Two layers:
//!
//! * [`ByteWriter`] / [`ByteReader`] — an in-memory little-endian byte
//!   codec with *checked* decoding: every read validates remaining length
//!   and returns [`crate::Error::Parse`] on truncation or malformed data,
//!   never panicking on attacker-controlled (or merely corrupted) bytes.
//!   This is the substrate of the checkpoint wire format
//!   ([`crate::mem::wire`]).
//! * [`write_frame`] / [`read_frame`] — tagged, length-prefixed frames
//!   over any [`Read`]/[`Write`] pair (pipes, files, sockets): the
//!   transport of the shard coordinator/worker protocol
//!   ([`crate::dse::shard`]).
//!
//! All integers are little-endian. Collections are `u32`-count-prefixed;
//! counts are validated against the remaining input *before* allocation so
//! a corrupt length cannot trigger an out-of-memory abort.

use crate::{Error, Result};
use std::io::{ErrorKind, Read, Write};

/// Frames larger than this are rejected by [`read_frame`] (a corrupt
/// length prefix must not trigger a gigantic allocation).
pub const MAX_FRAME_LEN: usize = 1 << 28;

fn truncated(what: &str) -> Error {
    Error::Parse(format!("wire: truncated input reading {what}"))
}

/// Growable little-endian byte sink.
#[derive(Debug, Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    /// Empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Consume the writer, yielding the encoded bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Append raw bytes with no length prefix (fixed-size fields only —
    /// the reader must know the exact length).
    pub fn put_raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Append one byte.
    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a `u16`, little-endian.
    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u32`, little-endian.
    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u64`, little-endian.
    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `bool` as one byte (`0` / `1`).
    pub fn put_bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    /// Append an `f64` by its IEEE-754 bit pattern (bit-exact round trip —
    /// the determinism guarantees of the DSE compare `f64::to_bits`).
    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    /// Append a `usize` as a `u64`.
    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    /// Append a `u32`-length-prefixed byte string.
    pub fn put_bytes(&mut self, bytes: &[u8]) {
        assert!(bytes.len() <= u32::MAX as usize, "wire: byte string too long");
        self.put_u32(bytes.len() as u32);
        self.buf.extend_from_slice(bytes);
    }

    /// Append a `u32`-length-prefixed UTF-8 string.
    pub fn put_str(&mut self, s: &str) {
        self.put_bytes(s.as_bytes());
    }
}

/// Checked little-endian byte source over a borrowed buffer.
#[derive(Debug)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    /// Reader over `buf`, positioned at the start.
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Take exactly `n` raw bytes (fixed-size fields only).
    pub fn get_raw(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(truncated("raw bytes"));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    /// Read one byte.
    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.get_raw(1)?[0])
    }

    /// Read a little-endian `u16`.
    pub fn get_u16(&mut self) -> Result<u16> {
        let b = self.get_raw(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    /// Read a little-endian `u32`.
    pub fn get_u32(&mut self) -> Result<u32> {
        let b = self.get_raw(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Read a little-endian `u64`.
    pub fn get_u64(&mut self) -> Result<u64> {
        let b = self.get_raw(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// Read a `bool`; any byte other than `0`/`1` is a parse error.
    pub fn get_bool(&mut self) -> Result<bool> {
        match self.get_u8()? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(Error::Parse(format!("wire: invalid bool byte {v:#04x}"))),
        }
    }

    /// Read an `f64` from its IEEE-754 bit pattern.
    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.get_u64()?))
    }

    /// Read a `usize` encoded as a `u64`.
    pub fn get_usize(&mut self) -> Result<usize> {
        usize::try_from(self.get_u64()?)
            .map_err(|_| Error::Parse("wire: usize field exceeds platform width".into()))
    }

    /// Read a collection count, validated so that `count *
    /// min_elem_bytes` elements can actually still be present in the
    /// remaining input — a corrupt count fails here instead of in a
    /// gigantic `Vec` allocation.
    pub fn get_count(&mut self, min_elem_bytes: usize) -> Result<usize> {
        let count = self.get_u32()? as usize;
        if count.saturating_mul(min_elem_bytes.max(1)) > self.remaining() {
            return Err(Error::Parse(format!(
                "wire: collection count {count} exceeds remaining input ({} bytes)",
                self.remaining()
            )));
        }
        Ok(count)
    }

    /// Read a `u32`-length-prefixed byte string.
    pub fn get_bytes(&mut self) -> Result<&'a [u8]> {
        let len = self.get_count(1)?;
        self.get_raw(len)
    }

    /// Read a `u32`-length-prefixed UTF-8 string.
    pub fn get_str(&mut self) -> Result<&'a str> {
        std::str::from_utf8(self.get_bytes()?)
            .map_err(|_| Error::Parse("wire: invalid UTF-8 in string field".into()))
    }

    /// Assert the input is fully consumed (trailing garbage is an error —
    /// it would mean the encoder and decoder disagree on the layout).
    pub fn finish(&self) -> Result<()> {
        if self.remaining() != 0 {
            return Err(Error::Parse(format!(
                "wire: {} trailing bytes after decoded value",
                self.remaining()
            )));
        }
        Ok(())
    }
}

/// Write one tagged frame: `u32` little-endian body length, one tag byte,
/// then the body. The writer is flushed so a pipe peer sees the frame
/// immediately (the shard protocol is strictly request/response).
pub fn write_frame(w: &mut impl Write, tag: u8, body: &[u8]) -> std::io::Result<()> {
    assert!(body.len() <= MAX_FRAME_LEN, "frame body too long");
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(&[tag])?;
    w.write_all(body)?;
    w.flush()
}

/// Read one tagged frame written by [`write_frame`].
///
/// Returns `Ok(None)` on a clean end-of-stream at a frame boundary (the
/// peer closed the connection between frames); end-of-stream *inside* a
/// frame is a parse error.
pub fn read_frame(r: &mut impl Read) -> Result<Option<(u8, Vec<u8>)>> {
    let mut len4 = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match r.read(&mut len4[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => return Err(truncated("frame length prefix")),
            Ok(n) => got += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(Error::Io(e)),
        }
    }
    let len = u32::from_le_bytes(len4) as usize;
    if len > MAX_FRAME_LEN {
        return Err(Error::Parse(format!("wire: frame length {len} exceeds limit")));
    }
    let mut tag = [0u8; 1];
    r.read_exact(&mut tag).map_err(|_| truncated("frame tag"))?;
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).map_err(|_| truncated("frame body"))?;
    Ok(Some((tag[0], body)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut w = ByteWriter::new();
        w.put_u8(0xAB);
        w.put_u16(0x1234);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(0x0123_4567_89AB_CDEF);
        w.put_bool(true);
        w.put_bool(false);
        w.put_f64(-0.5);
        w.put_usize(77);
        w.put_bytes(b"abc");
        w.put_str("héllo");
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_u8().unwrap(), 0xAB);
        assert_eq!(r.get_u16().unwrap(), 0x1234);
        assert_eq!(r.get_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.get_u64().unwrap(), 0x0123_4567_89AB_CDEF);
        assert!(r.get_bool().unwrap());
        assert!(!r.get_bool().unwrap());
        assert_eq!(r.get_f64().unwrap().to_bits(), (-0.5f64).to_bits());
        assert_eq!(r.get_usize().unwrap(), 77);
        assert_eq!(r.get_bytes().unwrap(), b"abc");
        assert_eq!(r.get_str().unwrap(), "héllo");
        r.finish().unwrap();
    }

    #[test]
    fn truncation_is_checked_at_every_prefix() {
        let mut w = ByteWriter::new();
        w.put_u64(42);
        w.put_str("hello");
        let bytes = w.into_bytes();
        for cut in 0..bytes.len() {
            let mut r = ByteReader::new(&bytes[..cut]);
            let decoded = r.get_u64().and_then(|_| r.get_str().map(str::to_string));
            assert!(decoded.is_err(), "prefix of {cut} bytes must fail");
        }
    }

    #[test]
    fn malformed_bool_and_count_are_errors() {
        let mut r = ByteReader::new(&[2]);
        assert!(r.get_bool().is_err());
        // Count claims 2^32-1 elements with 4 bytes of input left.
        let mut w = ByteWriter::new();
        w.put_u32(u32::MAX);
        w.put_u32(0);
        let bytes = w.into_bytes();
        assert!(ByteReader::new(&bytes).get_count(8).is_err());
        // A zero-min-element count is still bounded by the remaining input.
        assert!(ByteReader::new(&bytes).get_count(0).is_err());
    }

    #[test]
    fn trailing_bytes_are_an_error() {
        let mut w = ByteWriter::new();
        w.put_u32(7);
        w.put_u8(9);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_u32().unwrap(), 7);
        assert!(r.finish().is_err());
        assert_eq!(r.get_u8().unwrap(), 9);
        r.finish().unwrap();
    }

    #[test]
    fn frame_roundtrip_over_a_buffer() {
        let mut pipe = Vec::new();
        write_frame(&mut pipe, 1, b"first").unwrap();
        write_frame(&mut pipe, 2, &[]).unwrap();
        let mut cur = std::io::Cursor::new(pipe);
        assert_eq!(read_frame(&mut cur).unwrap(), Some((1, b"first".to_vec())));
        assert_eq!(read_frame(&mut cur).unwrap(), Some((2, Vec::new())));
        assert_eq!(read_frame(&mut cur).unwrap(), None, "clean EOF at frame boundary");
    }

    #[test]
    fn truncated_frame_is_an_error_not_eof() {
        let mut pipe = Vec::new();
        write_frame(&mut pipe, 1, b"payload").unwrap();
        for cut in 1..pipe.len() {
            let mut cur = std::io::Cursor::new(pipe[..cut].to_vec());
            assert!(read_frame(&mut cur).is_err(), "cut at {cut} must be an error");
        }
        let mut cur = std::io::Cursor::new(Vec::<u8>::new());
        assert_eq!(read_frame(&mut cur).unwrap(), None);
    }

    #[test]
    fn oversized_frame_length_is_rejected() {
        let mut bytes = ((MAX_FRAME_LEN + 1) as u32).to_le_bytes().to_vec();
        bytes.push(1);
        let mut cur = std::io::Cursor::new(bytes);
        assert!(read_frame(&mut cur).is_err());
    }
}

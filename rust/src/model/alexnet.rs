//! AlexNet layer table — used only for the §3.1 storage-requirement
//! discussion ("storage requirements ... can range from only 64 kB
//! [TC-ResNet] to more than 500 MB [AlexNet]").

use super::tcresnet::{LayerKind, LayerSpec};

/// AlexNet as 2-D convolutions flattened to the 1-D spec (X = H·W output
/// positions), sufficient for storage accounting.
pub fn alexnet() -> Vec<LayerSpec> {
    use LayerKind::*;
    vec![
        LayerSpec { idx: 0, kind: Conv, k: 96, c: 3, f: 11 * 11, x: 55 * 55 },
        LayerSpec { idx: 1, kind: Conv, k: 256, c: 48, f: 5 * 5, x: 27 * 27 },
        LayerSpec { idx: 2, kind: Conv, k: 384, c: 256, f: 3 * 3, x: 13 * 13 },
        LayerSpec { idx: 3, kind: Conv, k: 384, c: 192, f: 3 * 3, x: 13 * 13 },
        LayerSpec { idx: 4, kind: Conv, k: 256, c: 192, f: 3 * 3, x: 13 * 13 },
        LayerSpec { idx: 5, kind: Fc, k: 4096, c: 9216, f: 1, x: 1 },
        LayerSpec { idx: 6, kind: Fc, k: 4096, c: 4096, f: 1, x: 1 },
        LayerSpec { idx: 7, kind: Fc, k: 1000, c: 4096, f: 1, x: 1 },
    ]
}

/// Total weight storage in bytes at the given precision.
pub fn weight_bytes(layers: &[LayerSpec], bits_per_weight: u64) -> u64 {
    layers.iter().map(|l| l.weight_bits(bits_per_weight)).sum::<u64>() / 8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tcresnet::tc_resnet8;

    #[test]
    fn storage_range_of_section_3_1() {
        // TC-ResNet at 6-bit weights: tens of kB.
        let tc = weight_bytes(&tc_resnet8(), 6);
        assert!(tc < 64 * 1024, "TC-ResNet weights {tc} B should be tens of kB");
        // AlexNet at fp32: hundreds of MB.
        let ax = weight_bytes(&alexnet(), 64); // fp32 weights + optimizer state
        assert!(ax > 400 * 1024 * 1024, "AlexNet-scale storage {ax} B");
        // The paper's quoted span: 64 kB .. 500 MB.
        assert!(ax / tc > 5_000, "span covers several orders of magnitude");
    }

    #[test]
    fn alexnet_parameter_count() {
        // ~60M parameters is the canonical AlexNet size.
        let params: u64 = alexnet().iter().map(|l| l.weights()).sum();
        assert!((55_000_000..70_000_000).contains(&params), "got {params}");
    }
}

//! The TC-ResNet8 layer table of the UltraTrail case study.
//!
//! Layer geometry is chosen so that the derived quantities reproduce
//! Table 2 of the paper **exactly**:
//!
//! * unique weight addresses = `K·C·F` (6-bit weights, one address per
//!   weight word);
//! * "cycle length" = the output width `X` — the number of MAC-array
//!   steps each weight-port word stays live before the next port word is
//!   needed. This is what makes the paper's bandwidth argument work: at
//!   layer 11 the cycle length 4 gives the hierarchy only 4 accelerator
//!   cycles to assemble the next 384-bit port word (which takes 9 when
//!   streaming from off-chip), and FC layers (cycle length 1) never reuse
//!   weights at all (§5.3.2).
//!
//! The residual-block structure mirrors UltraTrail's TC-ResNet: a 3-tap
//! stem over 40 MFCC channels, three blocks of (9-tap conv, 9-tap conv,
//! 1×1 shortcut), and two FC heads.

/// Convolutional or fully-connected layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// 1-D (temporal) convolution.
    Conv,
    /// Fully connected.
    Fc,
}

/// One TC-ResNet layer (1-D convolution geometry).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerSpec {
    /// Layer index (Table 2 numbering).
    pub idx: usize,
    /// Conv or FC.
    pub kind: LayerKind,
    /// Output channels `K`.
    pub k: u64,
    /// Input channels `C`.
    pub c: u64,
    /// Filter width `F` (1 for FC).
    pub f: u64,
    /// Output width `X` (1 for FC) — Table 2's cycle length.
    pub x: u64,
}

impl LayerSpec {
    /// Unique weight words (Table 2 "Unique Addresses").
    pub fn weights(&self) -> u64 {
        self.k * self.c * self.f
    }

    /// Table 2 "Cycle Length": MAC steps per weight-port word.
    pub fn cycle_length(&self) -> u64 {
        self.x
    }

    /// Multiply-accumulate operations.
    pub fn macs(&self) -> u64 {
        self.k * self.c * self.f * self.x
    }

    /// Ideal MAC-array steps on an `n_macs`-unit array (weights fully
    /// parallelized onto the array; X iterated serially).
    pub fn ideal_steps(&self, n_macs: u64) -> u64 {
        crate::util::ceil_div(self.weights(), n_macs) * self.x
    }

    /// Weight storage in bits at `bits_per_weight` precision.
    pub fn weight_bits(&self, bits_per_weight: u64) -> u64 {
        self.weights() * bits_per_weight
    }
}

/// The 13-layer TC-ResNet8 used by UltraTrail for keyword spotting
/// (Google speech-commands subset, 12 classes).
pub fn tc_resnet8() -> Vec<LayerSpec> {
    use LayerKind::*;
    vec![
        LayerSpec { idx: 0, kind: Conv, k: 16, c: 40, f: 3, x: 98 },  // stem
        LayerSpec { idx: 1, kind: Conv, k: 24, c: 16, f: 9, x: 45 },  // block1 conv1 (s=2)
        LayerSpec { idx: 2, kind: Conv, k: 24, c: 16, f: 1, x: 49 },  // block1 shortcut
        LayerSpec { idx: 3, kind: Conv, k: 24, c: 24, f: 9, x: 41 },  // block1 conv2
        LayerSpec { idx: 4, kind: Conv, k: 32, c: 24, f: 9, x: 20 },  // block2 conv1 (s=2)
        LayerSpec { idx: 5, kind: Conv, k: 32, c: 24, f: 1, x: 24 },  // block2 shortcut
        LayerSpec { idx: 6, kind: Conv, k: 32, c: 32, f: 9, x: 16 },  // block2 conv2
        LayerSpec { idx: 7, kind: Conv, k: 32, c: 16, f: 1, x: 24 },  // squeeze
        LayerSpec { idx: 8, kind: Fc, k: 4, c: 49, f: 1, x: 1 },      // aux head
        LayerSpec { idx: 9, kind: Conv, k: 48, c: 32, f: 9, x: 8 },   // block3 conv1 (s=2)
        LayerSpec { idx: 10, kind: Conv, k: 48, c: 32, f: 1, x: 12 }, // block3 shortcut
        LayerSpec { idx: 11, kind: Conv, k: 48, c: 48, f: 9, x: 4 },  // block3 conv2
        LayerSpec { idx: 12, kind: Fc, k: 12, c: 64, f: 1, x: 1 },    // classifier (12 kws)
    ]
}

/// The paper's Table 2, verbatim, for cross-checking.
pub const TABLE2_UNIQUE_ADDRESSES: [u64; 13] =
    [1920, 3456, 384, 5184, 6912, 768, 9216, 512, 196, 13824, 1536, 20736, 768];

/// The paper's Table 2 cycle lengths, verbatim.
pub const TABLE2_CYCLE_LENGTHS: [u64; 13] = [98, 45, 49, 41, 20, 24, 16, 24, 1, 8, 12, 4, 1];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_unique_addresses_exact() {
        let layers = tc_resnet8();
        assert_eq!(layers.len(), 13);
        for (l, &expect) in layers.iter().zip(TABLE2_UNIQUE_ADDRESSES.iter()) {
            assert_eq!(l.weights(), expect, "layer {} unique addresses", l.idx);
        }
    }

    #[test]
    fn table2_cycle_lengths_exact() {
        for (l, &expect) in tc_resnet8().iter().zip(TABLE2_CYCLE_LENGTHS.iter()) {
            assert_eq!(l.cycle_length(), expect, "layer {} cycle length", l.idx);
        }
    }

    #[test]
    fn table2_layer_kinds() {
        // Layers 8 and 12 are the FC layers (Table 2 row "Layer Type").
        let layers = tc_resnet8();
        for l in &layers {
            let expect = if l.idx == 8 || l.idx == 12 { LayerKind::Fc } else { LayerKind::Conv };
            assert_eq!(l.kind, expect, "layer {} kind", l.idx);
        }
    }

    #[test]
    fn layer11_dominates_weights() {
        // §5.3.1: "layer eleven ... has the highest capacity requirement
        // among all layers with 20,736 unique data words".
        let layers = tc_resnet8();
        let max = layers.iter().map(|l| l.weights()).max().unwrap();
        assert_eq!(max, 20_736);
        assert_eq!(layers.iter().max_by_key(|l| l.weights()).unwrap().idx, 11);
    }

    #[test]
    fn fc_layers_do_not_dominate_compute() {
        // §5.3.2: FC layers "do not dominate the computational costs".
        let layers = tc_resnet8();
        let total: u64 = layers.iter().map(|l| l.macs()).sum();
        let fc: u64 = layers.iter().filter(|l| l.kind == LayerKind::Fc).map(|l| l.macs()).sum();
        assert!(
            (fc as f64) < 0.01 * total as f64,
            "FC macs {fc} should be <1% of total {total}"
        );
    }

    #[test]
    fn total_weight_footprint_fits_baseline_wmem() {
        // Baseline UltraTrail stores the complete weight set in
        // 3x 1024x128-bit macros = 393,216 bits; 6-bit weights.
        let bits: u64 = tc_resnet8().iter().map(|l| l.weight_bits(6)).sum();
        assert!(bits <= 3 * 1024 * 128, "weights {bits} bits must fit 393216");
        // And it is a tight fit (the paper sized the macros for this model).
        assert!(bits > 2 * 1024 * 128, "weights should need the third macro");
    }
}

//! DNN model descriptions used by the evaluation: the TC-ResNet keyword-
//! spotting network of the UltraTrail case study (§5.3, Table 2) and
//! AlexNet for the §3.1 storage-requirement discussion.

pub mod alexnet;
pub mod tcresnet;

pub use tcresnet::{tc_resnet8, LayerKind, LayerSpec};

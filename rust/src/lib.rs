//! # memhier — a configurable memory hierarchy for NN hardware accelerators
//!
//! Reproduction of *“A Configurable and Efficient Memory Hierarchy for
//! Neural Network Hardware Accelerator”* (Bause, Palomero Bernardo,
//! Bringmann, 2024). The paper's SystemVerilog framework is reproduced as a
//! **cycle-accurate simulator** with the same per-cycle semantics (write-
//! over-read, single-/dual-ported banks, CDC input-buffer handshake, MCU
//! pattern engine, output shift register), extended with the §6
//! double-buffered (ping-pong) level kind as a pluggable per-level
//! choice, plus the substrates the paper's evaluation depends on:
//!
//! * [`pattern`] — the six memory-access-pattern families of §3.2 and a
//!   trace classifier.
//! * [`mem`] — the memory hierarchy itself (§4): off-chip model, input
//!   buffer, 1–5 levels, MCU (Listing 1), OSR.
//! * [`sim`] — two-clock-domain cycle simulation substrate with stats,
//!   VCD-style waveform capture (Fig 4), warm-reusable batched
//!   co-simulation sessions ([`sim::batch`]), and full mid-run
//!   checkpointing ([`mem::HierarchyCheckpoint`]: suspend a run, resume
//!   it bit-identically on any identically armed hierarchy).
//! * [`cost`] — parametric SRAM macro area/power model calibrated to the
//!   paper's synthesis anchors (Figs 7, 9, 12).
//! * [`loopnest`] — DNN loop-nest unrolling and memory-trace analysis
//!   (§5.3, Table 2).
//! * [`model`] — TC-ResNet and AlexNet layer tables.
//! * [`accel`] — the UltraTrail 8×8 accelerator model and case study
//!   (§5.3.1–5.3.2).
//! * [`dse`] — design-space exploration over hierarchy configurations:
//!   exhaustive, pooled (warm session per worker), and successive-halving
//!   with checkpoint-resumed rungs (screened work is paid exactly once).
//! * [`runtime`] — PJRT client that loads the AOT-compiled TC-ResNet
//!   (JAX + Pallas, lowered to HLO text at build time) and executes it.
//! * [`coordinator`] — the KWS serving driver: streams weights through the
//!   simulated hierarchy while running real inference via [`runtime`].
//! * [`report`] — regenerates every table and figure of the evaluation.
//!
//! In-tree infrastructure (the build environment is offline):
//! [`util`] (PRNG, wide bit-words, CLI), [`config`] (TOML-subset parser),
//! [`benchkit`] (criterion-style harness), [`testkit`] (property testing).
//!
//! ## Quickstart
//!
//! ```
//! use memhier::config::HierarchyConfig;
//! use memhier::mem::Hierarchy;
//! use memhier::pattern::PatternProgram;
//!
//! // Two levels: L0 1024 x 32-bit single-ported, L1 128 x 32-bit dual-ported.
//! let cfg = HierarchyConfig::builder()
//!     .offchip(32, 20, 1.0)
//!     .level(32, 1024, 1, 1)
//!     .level(32, 128, 1, 2)
//!     .build()
//!     .unwrap();
//! // Shifted-cyclic pattern: cycle length 64, inter-cycle shift 8.
//! let prog = PatternProgram::shifted_cyclic(0, 64, 8).with_outputs(1_000);
//! let mut h = Hierarchy::new(&cfg).unwrap();
//! h.load_program(&prog).unwrap();
//! let out = h.run_to_outputs(1_000).unwrap();
//! assert_eq!(out.outputs, 1_000);
//! ```
//!
//! ## Warm sessions: many programs, one hierarchy
//!
//! The framework is per-layer reconfigurable: the same physical hierarchy
//! executes a different access pattern for each DNN layer. A
//! [`sim::batch::Session`] mirrors that — programs load onto a warm
//! hierarchy whose components are re-armed in place (no reallocation),
//! with results bit-identical to fresh construction:
//!
//! ```
//! use memhier::config::HierarchyConfig;
//! use memhier::pattern::PatternProgram;
//! use memhier::sim::batch::Session;
//!
//! let cfg = HierarchyConfig::builder()
//!     .offchip(32, 20, 1.0)
//!     .level(32, 256, 1, 2)
//!     .build()
//!     .unwrap();
//! let mut session = Session::new(&cfg).unwrap();
//! // Back-to-back "layers" on one warm hierarchy.
//! let layers = [
//!     PatternProgram::cyclic(0, 64).with_outputs(640),
//!     PatternProgram::sequential(4_096, 256),
//! ];
//! let results = session.run_batch(&layers).unwrap();
//! assert_eq!(results[0].stats.outputs, 640);
//! assert_eq!(results[1].stats.outputs, 256);
//! ```

pub mod accel;
pub mod benchkit;
pub mod config;
pub mod coordinator;
pub mod cost;
pub mod dse;
pub mod loopnest;
pub mod mem;
pub mod model;
pub mod pattern;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod testkit;
pub mod util;

/// Crate-wide error type (manual impls — the build environment is offline,
/// so `thiserror` is not available).
#[derive(Debug)]
pub enum Error {
    /// Invalid framework configuration (§4.1 parameter constraints).
    Config(String),
    /// Invalid pattern program for the configured hierarchy.
    Pattern(String),
    /// Simulation reached an inconsistent state (would be a hardware bug).
    Integrity {
        /// Internal cycle at which the inconsistency was detected.
        cycle: u64,
        /// Description of the violated invariant.
        msg: String,
    },
    /// Config-file / CLI parse errors.
    Parse(String),
    /// Runtime (PJRT / artifact) errors.
    Runtime(String),
    /// I/O error.
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Config(m) => write!(f, "configuration error: {m}"),
            Error::Pattern(m) => write!(f, "pattern error: {m}"),
            Error::Integrity { cycle, msg } => {
                write!(f, "simulation integrity error at cycle {cycle}: {msg}")
            }
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

//! # memhier — a configurable memory hierarchy for NN hardware accelerators
//!
//! Reproduction of *“A Configurable and Efficient Memory Hierarchy for
//! Neural Network Hardware Accelerator”* (Bause, Palomero Bernardo,
//! Bringmann, 2024). The paper's SystemVerilog framework is reproduced as a
//! **cycle-accurate simulator** with the same per-cycle semantics (write-
//! over-read, single-/dual-ported banks, CDC input-buffer handshake, MCU
//! pattern engine, output shift register), plus the substrates the paper's
//! evaluation depends on:
//!
//! * [`pattern`] — the six memory-access-pattern families of §3.2 and a
//!   trace classifier.
//! * [`mem`] — the memory hierarchy itself (§4): off-chip model, input
//!   buffer, 1–5 levels, MCU (Listing 1), OSR.
//! * [`sim`] — two-clock-domain cycle simulation substrate with stats and
//!   VCD-style waveform capture (Fig 4).
//! * [`cost`] — parametric SRAM macro area/power model calibrated to the
//!   paper's synthesis anchors (Figs 7, 9, 12).
//! * [`loopnest`] — DNN loop-nest unrolling and memory-trace analysis
//!   (§5.3, Table 2).
//! * [`model`] — TC-ResNet and AlexNet layer tables.
//! * [`accel`] — the UltraTrail 8×8 accelerator model and case study
//!   (§5.3.1–5.3.2).
//! * [`dse`] — design-space exploration over hierarchy configurations.
//! * [`runtime`] — PJRT client that loads the AOT-compiled TC-ResNet
//!   (JAX + Pallas, lowered to HLO text at build time) and executes it.
//! * [`coordinator`] — the KWS serving driver: streams weights through the
//!   simulated hierarchy while running real inference via [`runtime`].
//! * [`report`] — regenerates every table and figure of the evaluation.
//!
//! In-tree infrastructure (the build environment is offline):
//! [`util`] (PRNG, wide bit-words, CLI), [`config`] (TOML-subset parser),
//! [`benchkit`] (criterion-style harness), [`testkit`] (property testing).
//!
//! ## Quickstart
//!
//! ```
//! use memhier::config::HierarchyConfig;
//! use memhier::mem::Hierarchy;
//! use memhier::pattern::PatternProgram;
//!
//! // Two levels: L0 1024 x 32-bit single-ported, L1 128 x 32-bit dual-ported.
//! let cfg = HierarchyConfig::builder()
//!     .offchip(32, 20, 1.0)
//!     .level(32, 1024, 1, 1)
//!     .level(32, 128, 1, 2)
//!     .build()
//!     .unwrap();
//! // Shifted-cyclic pattern: cycle length 64, inter-cycle shift 8.
//! let prog = PatternProgram::shifted_cyclic(0, 64, 8).with_outputs(1_000);
//! let mut h = Hierarchy::new(&cfg).unwrap();
//! h.load_program(&prog).unwrap();
//! let out = h.run_to_outputs(1_000);
//! assert_eq!(out.outputs, 1_000);
//! ```

pub mod accel;
pub mod benchkit;
pub mod config;
pub mod coordinator;
pub mod cost;
pub mod dse;
pub mod loopnest;
pub mod mem;
pub mod model;
pub mod pattern;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod testkit;
pub mod util;

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Invalid framework configuration (§4.1 parameter constraints).
    #[error("configuration error: {0}")]
    Config(String),
    /// Invalid pattern program for the configured hierarchy.
    #[error("pattern error: {0}")]
    Pattern(String),
    /// Simulation reached an inconsistent state (would be a hardware bug).
    #[error("simulation integrity error at cycle {cycle}: {msg}")]
    Integrity { cycle: u64, msg: String },
    /// Config-file / CLI parse errors.
    #[error("parse error: {0}")]
    Parse(String),
    /// Runtime (PJRT / artifact) errors.
    #[error("runtime error: {0}")]
    Runtime(String),
    /// I/O error.
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

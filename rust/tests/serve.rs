//! Serving-tier integration tests: batch formation, the warmed-vs-cold
//! determinism contract across the pattern-family × level-kind matrix,
//! deterministic statistics under synchronous warming, and typed
//! load-shed accounting.

use memhier::config::HierarchyConfig;
use memhier::coordinator::warm::park_session;
use memhier::coordinator::{
    synth_request, CoordinatorStats, KwsRequest, KwsResult, KwsServer, ServerConfig, WarmingMode,
    TENANT_STRIDE,
};
use memhier::mem::wire::decode_checkpoint;
use memhier::mem::Hierarchy;
use memhier::pattern::PatternProgram;
use memhier::sim::batch::Session;
use std::collections::BTreeMap;
use std::time::Duration;

/// Level-kind matrix: standard narrow/wide+OSR, single-level, case-study
/// shape (4x clock, deep input buffer, preload), and both double-buffered
/// placements.
fn config_matrix() -> Vec<HierarchyConfig> {
    vec![
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level(32, 128, 1, 2)
            .build()
            .unwrap(),
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(128, 128, 1, 1)
            .level(128, 32, 1, 2)
            .osr(256, vec![32])
            .build()
            .unwrap(),
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 256, 1, 2)
            .build()
            .unwrap(),
        HierarchyConfig::builder()
            .offchip(32, 24, 4.0)
            .ib_depth(8)
            .level(128, 104, 1, 2)
            .osr(384, vec![384])
            .preload(true)
            .build()
            .unwrap(),
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level_double_buffered(32, 128)
            .build()
            .unwrap(),
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level_double_buffered(32, 64)
            .build()
            .unwrap(),
    ]
}

/// One program per §3.2 pattern family (sized for every matrix config).
fn pattern_programs() -> Vec<PatternProgram> {
    vec![
        PatternProgram::sequential(0, 384),
        PatternProgram::strided(64, 4, 384),
        PatternProgram::cyclic(0, 64).with_outputs(640),
        PatternProgram::cyclic(0, 256).with_outputs(1_024),
        PatternProgram::shifted_cyclic(0, 96, 16).with_outputs(960),
        PatternProgram::shifted_cyclic(0, 64, 32).with_skip_shift(1).with_outputs(768),
    ]
}

fn sim_server(cfg: ServerConfig) -> KwsServer {
    KwsServer::sim_only(cfg).expect("sim-only server")
}

fn tenant_request(id: u64, tenant: u64) -> KwsRequest {
    synth_request(id).with_weight_base(tenant * TENANT_STRIDE)
}

#[test]
fn empty_batch_and_stream_are_noops() {
    // The old serving path asserted non-emptiness; an empty batch must be
    // an Ok no-op, not a panic.
    let mut srv = sim_server(ServerConfig::default());
    assert!(srv.serve_batch(&[]).unwrap().is_empty());
    assert!(srv.serve_stream(Vec::new()).unwrap().is_empty());
    assert_eq!(srv.stats().served, 0);
    assert_eq!(srv.stats().batches, 0);
}

#[test]
fn stream_respects_max_batch_and_preserves_order() {
    let mut srv = sim_server(ServerConfig { max_batch: 4, ..ServerConfig::default() });
    let requests: Vec<_> = (0..21u64).map(synth_request).collect();
    let results = srv.serve_stream(requests).unwrap();
    assert_eq!(results.len(), 21);
    // Submission order is service order.
    let ids: Vec<u64> = results.iter().map(|r| r.id).collect();
    assert_eq!(ids, (0..21).collect::<Vec<_>>());
    // Batch membership is observable and bounded by max_batch.
    let mut sizes: BTreeMap<u64, usize> = BTreeMap::new();
    for r in &results {
        *sizes.entry(r.batch_seq).or_default() += 1;
    }
    assert!(sizes.values().all(|&n| n <= 4), "batch exceeded max_batch: {sizes:?}");
    // Batch sequence never decreases along the result order.
    for w in results.windows(2) {
        assert!(w[0].batch_seq <= w[1].batch_seq);
    }
    assert_eq!(srv.stats().served, 21);
    assert_eq!(srv.stats().batches as usize, sizes.len());
    // Queue wait and service time are recorded for every request.
    assert_eq!(srv.stats().queue_wait.count(), 21);
    assert_eq!(srv.stats().service.count(), 21);
}

#[test]
fn deadline_closes_forming_batch_early() {
    // Without an SLO, a 10 s linger would hold the first request until the
    // stream drains; its 5 ms deadline must close the batch long before
    // the second request arrives at 300 ms — two separate batches.
    let mut srv = sim_server(ServerConfig {
        max_batch: 8,
        max_linger: Duration::from_secs(10),
        ..ServerConfig::default()
    });
    let trace = vec![
        memhier::coordinator::TracedRequest {
            at: Duration::ZERO,
            req: synth_request(0).with_slo(Duration::from_millis(5)),
        },
        memhier::coordinator::TracedRequest {
            at: Duration::from_millis(300),
            req: synth_request(1).with_slo(Duration::from_millis(5)),
        },
    ];
    let t0 = std::time::Instant::now();
    let results = srv.serve_trace(trace).unwrap();
    let wall = t0.elapsed();
    assert_eq!(results.len(), 2);
    assert_ne!(
        results[0].batch_seq, results[1].batch_seq,
        "deadline must close the first batch before the second arrival"
    );
    assert!(wall < Duration::from_secs(5), "the 10 s linger must not be reached: {wall:?}");

    // Conversely, without deadlines a linger holds the batch open: two
    // closely spaced arrivals share one batch despite a momentarily empty
    // channel between them.
    let mut srv = sim_server(ServerConfig {
        max_batch: 8,
        max_linger: Duration::from_millis(500),
        ..ServerConfig::default()
    });
    let trace = vec![
        memhier::coordinator::TracedRequest { at: Duration::ZERO, req: synth_request(2) },
        memhier::coordinator::TracedRequest {
            at: Duration::from_millis(60),
            req: synth_request(3),
        },
    ];
    let results = srv.serve_trace(trace).unwrap();
    assert_eq!(results.len(), 2);
    assert_eq!(
        results[0].batch_seq, results[1].batch_seq,
        "linger must hold the batch open for the second arrival"
    );
}

#[test]
fn parked_state_bit_identical_to_cold_runs_across_matrix() {
    // The speculative warmer's contract, asserted for every pattern
    // family × level kind: parked supply cycles equal fresh cold runs,
    // and a session resumed from the wire-encoded checkpoint continues
    // bit-identically to the session that parked it.
    let continuation = PatternProgram::cyclic(0, 64).with_outputs(640);
    for (ci, cfg) in config_matrix().iter().enumerate() {
        let mut warm = Session::new(cfg).unwrap();
        let progs = pattern_programs();
        let parked = park_session(&mut warm, &progs).unwrap();
        assert_eq!(parked.supplies.len(), progs.len());
        for (pi, prog) in progs.iter().enumerate() {
            let mut fresh = Hierarchy::new(cfg).unwrap();
            fresh.load_program(prog).unwrap();
            let cold = fresh.run().unwrap();
            assert_eq!(
                parked.supplies[pi], cold.stats.internal_cycles,
                "cfg {ci}, pattern {pi}: parked supply != cold simulation"
            );
        }
        // Round-trip the parked state through the wire format into a new
        // session; both must then simulate the continuation identically.
        let (ck, bound) = decode_checkpoint(&parked.blob).unwrap();
        let mut resumed = Session::new(cfg).unwrap();
        resumed.resume(&ck, &bound).unwrap();
        let a = warm.run_program(&continuation).unwrap();
        let b = resumed.run_program(&continuation).unwrap();
        assert_eq!(
            a.stats, b.stats,
            "cfg {ci}: resumed session diverged from the parking session"
        );
    }
}

/// Assert the deterministic slice of [`CoordinatorStats`] matches
/// (wall-clock histograms excluded — they are the only nondeterminism).
fn assert_det_stats_eq(x: &CoordinatorStats, y: &CoordinatorStats) {
    assert_eq!(x.served, y.served);
    assert_eq!(x.batches, y.batches);
    assert_eq!(x.shed, y.shed);
    assert_eq!(x.shed_queue_full, y.shed_queue_full);
    assert_eq!(x.shed_tenant_cap, y.shed_tenant_cap);
    assert_eq!(x.deadline_miss, y.deadline_miss);
    assert_eq!(x.cache_hits, y.cache_hits);
    assert_eq!(x.warm_hits, y.warm_hits);
    assert_eq!(x.cold_sims, y.cold_sims);
    assert_eq!(x.accel_cycles, y.accel_cycles, "accel-cycle histograms diverged");
    assert_eq!(x.tenants, y.tenants, "per-tenant counters diverged");
}

#[test]
fn synchronous_warming_is_deterministic_and_bit_identical_to_cold() {
    // Synchronous warming makes the entire pipeline a pure function of
    // the admitted request sequence: two identical runs must agree on
    // every counter, every percentile of the accel-cycle histogram, and
    // every served cycle count — and those counts must equal a
    // warming-off server's cold simulations.
    let cfg = || ServerConfig {
        max_batch: 8,
        max_cached_bases: 2,
        warming: WarmingMode::Synchronous,
        warm_capacity: 8,
        warm_ahead: 4,
        ..ServerConfig::default()
    };
    let requests: Vec<KwsRequest> =
        (0..48u64).map(|i| tenant_request(i, i % 6)).collect();
    let run = |mut srv: KwsServer| -> (Vec<KwsResult>, KwsServer) {
        let mut out = Vec::new();
        for chunk in requests.chunks(6) {
            out.extend(srv.serve_batch(chunk).unwrap());
        }
        (out, srv)
    };
    let (ra, sa) = run(sim_server(cfg()));
    let (rb, sb) = run(sim_server(cfg()));
    assert_eq!(ra.len(), 48);
    for (x, y) in ra.iter().zip(rb.iter()) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.accel_cycles, y.accel_cycles, "request {}: cycles diverged", x.id);
        assert_eq!(x.batch_seq, y.batch_seq);
        assert_eq!(x.class, y.class, "sim-only classifier must be deterministic");
    }
    assert_det_stats_eq(sa.stats(), sb.stats());
    assert_eq!(sa.stats().accel_cycles.p50(), sb.stats().accel_cycles.p50());
    assert_eq!(sa.stats().accel_cycles.p99(), sb.stats().accel_cycles.p99());
    assert_eq!(sa.warm_stats(), sb.warm_stats(), "warm-store traffic diverged");
    // The round-robin over 6 tenants against a 2-entry cycle cache must
    // exercise all three cycle sources.
    let s = sa.stats();
    assert!(s.warm_hits > 0, "synchronous warming never produced a warm hit: {s:?}");
    assert!(s.cold_sims > 0, "expected cold simulations before the warmer catches up");
    assert!(s.cache_hits + s.warm_hits + s.cold_sims == s.served);

    // Warming off: same requests, same cycle counts (the determinism
    // contract at the server level), zero warm activity.
    let mut off = sim_server(ServerConfig {
        max_batch: 8,
        max_cached_bases: 2,
        warming: WarmingMode::Off,
        ..ServerConfig::default()
    });
    let mut cold = Vec::new();
    for chunk in requests.chunks(6) {
        cold.extend(off.serve_batch(chunk).unwrap());
    }
    for (x, y) in ra.iter().zip(cold.iter()) {
        assert_eq!(
            x.accel_cycles, y.accel_cycles,
            "request {}: warmed serving changed a cycle count",
            x.id
        );
    }
    assert_eq!(off.stats().warm_hits, 0);
    assert!(off.warm_stats().is_none());
}

#[test]
fn overload_sheds_with_typed_queue_accounting() {
    // A depth-1 queue under an instantaneous 64-request flood must shed
    // most of the flood as QueueFull — and account for every request.
    let mut srv = sim_server(ServerConfig {
        max_batch: 1,
        queue_depth: 1,
        max_cached_bases: 4,
        ..ServerConfig::default()
    });
    let requests: Vec<_> = (0..64u64).map(|i| tenant_request(i, i % 16)).collect();
    let results = srv.serve_stream(requests).unwrap();
    let s = srv.stats();
    assert_eq!(results.len() as u64 + s.shed, 64, "every request served or shed");
    assert!(s.shed > 0, "a depth-1 queue cannot absorb an instantaneous flood");
    assert_eq!(s.shed, s.shed_queue_full, "all sheds must be typed QueueFull");
    assert_eq!(s.shed_tenant_cap, 0);
    let tenant_sheds: u64 = s.tenants.values().map(|t| t.shed).sum();
    assert_eq!(tenant_sheds, s.shed, "per-tenant shed accounting must add up");
}

#[test]
fn tenant_cap_preserves_fairness_under_flood() {
    // One tenant floods; the capped queue still admits the other tenant.
    let mut srv = sim_server(ServerConfig {
        max_batch: 4,
        queue_depth: 0,
        tenant_cap: 1,
        ..ServerConfig::default()
    });
    let mut requests: Vec<_> = (0..32u64).map(|i| tenant_request(i, 1)).collect();
    requests.push(tenant_request(100, 2));
    let results = srv.serve_stream(requests).unwrap();
    let s = srv.stats();
    assert_eq!(results.len() as u64 + s.shed, 33);
    assert!(s.shed_tenant_cap > 0, "the flooding tenant must hit its cap");
    assert_eq!(s.shed, s.shed_tenant_cap);
    let other = s.tenants.get(&(2 * TENANT_STRIDE)).copied().unwrap_or_default();
    assert_eq!(other.served, 1, "the capped flood must not starve the other tenant");
    assert_eq!(other.shed, 0);
}

#[test]
fn serving_path_surfaces_typed_errors() {
    // An out-of-address-space weight base is a typed error, not a panic —
    // and the server survives it.
    let mut srv = sim_server(ServerConfig::default());
    let bad = synth_request(0).with_weight_base(u64::MAX);
    match srv.serve_batch(&[bad]) {
        Err(memhier::Error::Pattern(msg)) => {
            assert!(msg.contains("weight_base"), "unexpected message: {msg}")
        }
        other => panic!("expected a typed pattern error, got {other:?}"),
    }
    let ok = srv.serve_batch(&[synth_request(1)]).unwrap();
    assert_eq!(ok.len(), 1);
    assert!(ok[0].accel_cycles.is_some());

    // A missing PJRT artifact surfaces as a runtime error at construction.
    match KwsServer::new(std::path::Path::new("/nonexistent/model.hlo"), ServerConfig::default())
    {
        Err(memhier::Error::Runtime(_)) => {}
        other => panic!("expected a runtime error, got {:?}", other.map(|_| "server")),
    }
}

#[test]
fn slo_misses_are_counted() {
    // A zero SLO is missed by construction; a generous one is met.
    let mut srv = sim_server(ServerConfig::default());
    let strict = synth_request(0).with_slo(Duration::ZERO);
    let lax = synth_request(1).with_slo(Duration::from_secs(3600));
    let results = srv.serve_batch(&[strict, lax]).unwrap();
    assert!(results[0].deadline_missed, "a zero SLO cannot be met");
    assert!(!results[1].deadline_missed, "an hour-long SLO must be met");
    assert_eq!(srv.stats().deadline_miss, 1);
    let t = srv.stats().tenants.get(&0).copied().unwrap_or_default();
    assert_eq!(t.deadline_miss, 1);
}

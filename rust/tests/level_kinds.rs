//! Level-kind integration suite: the double-buffered (ping-pong) level
//! must hold the repo's strongest invariants —
//!
//! 1. **differential correctness**: the timed simulator's output stream
//!    equals the [`FunctionalModel`]'s for every pattern family, with
//!    cycle counts inside the analytic bounds;
//! 2. **warm == cold bit-identity**: re-armed sessions (including
//!    re-arms that *switch the level kind*) are indistinguishable from
//!    fresh hierarchies;
//! 3. **DSE acceptance**: a sweep over both kinds produces a Pareto
//!    front where a double-buffered design strictly dominates a standard
//!    one on (area, cycles) for a streaming workload, and the pooled and
//!    successive-halving fronts stay bitwise-identical to the serial
//!    exhaustive front with kinds enabled.

use memhier::config::{HierarchyConfig, LevelKind};
use memhier::dse::{
    explore, explore_halving, DesignPoint, HalvingSchedule, HierarchyPool, KindChoice,
    SearchSpace,
};
use memhier::mem::{FunctionalModel, Hierarchy, RunResult};
use memhier::pattern::PatternProgram;
use memhier::sim::batch::Session;

/// Hierarchies with at least one double-buffered level, covering the
/// positions a ping-pong level can occupy.
fn db_configs() -> Vec<HierarchyConfig> {
    vec![
        // Ping-pong behind a (residency-capable) standard level.
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level_double_buffered(32, 128)
            .build()
            .unwrap(),
        // Ping-pong feeding a standard level.
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level_double_buffered(32, 512)
            .level(32, 128, 1, 2)
            .build()
            .unwrap(),
        // Pure ping-pong hierarchy (streams everything).
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level_double_buffered(32, 64)
            .build()
            .unwrap(),
        // Ping-pong with preloading.
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level_double_buffered(32, 128)
            .preload(true)
            .build()
            .unwrap(),
    ]
}

/// One program per §3.2 pattern family.
fn pattern_programs() -> Vec<PatternProgram> {
    vec![
        PatternProgram::sequential(0, 384),
        PatternProgram::strided(64, 4, 384),
        PatternProgram::cyclic(0, 64).with_outputs(640),
        PatternProgram::cyclic(0, 256).with_outputs(1_024),
        PatternProgram::shifted_cyclic(0, 96, 16).with_outputs(960),
        PatternProgram::shifted_cyclic(0, 64, 32).with_skip_shift(1).with_outputs(768),
    ]
}

fn run_fresh(cfg: &HierarchyConfig, prog: &PatternProgram) -> RunResult {
    let mut h = Hierarchy::new(cfg).expect("config valid");
    h.set_collect(true);
    h.load_program(prog).expect("program loads");
    h.run().expect("simulation succeeds")
}

#[test]
fn differential_double_buffered_all_families() {
    for cfg in &db_configs() {
        for prog in &pattern_programs() {
            let what = format!(
                "cfg {:?}, pattern {:?}",
                cfg.levels.iter().map(|l| (l.kind.label(), l.ram_depth)).collect::<Vec<_>>(),
                prog.output
            );
            let f = FunctionalModel::new(cfg, prog).unwrap();
            let r = run_fresh(cfg, prog);
            // Flatten the simulator outputs to unit granularity; verify
            // was on, so addresses/payloads were already checked inline —
            // compare the stream against the oracle anyway.
            let mut sim_units = Vec::new();
            for out in &r.outputs {
                for (j, &a) in out.addrs.iter().enumerate() {
                    sim_units.push((a, out.word.bits(j as u32 * 32, 32)));
                }
            }
            assert_eq!(sim_units, f.expected_units(), "{what}: stream mismatch");
            assert_eq!(r.stats.outputs, f.expected_output_count(), "{what}");
            assert_eq!(r.stats.offchip_reads, f.expected_offchip_reads(), "{what}");
            let cyc = r.stats.internal_cycles;
            // The analytic lower bound models a cold start; a preloaded
            // run legitimately beats it (the fill happened off the
            // measured clock), so only cold configs check it.
            if !cfg.preload {
                assert!(cyc >= f.cycle_lower_bound(), "{what}: cycles {cyc} below bound");
            }
            assert!(
                cyc <= f.cycle_upper_bound(),
                "{what}: cycles {cyc} above bound {}",
                f.cycle_upper_bound()
            );
        }
    }
}

#[test]
fn warm_equals_cold_for_double_buffered() {
    for cfg in &db_configs() {
        let mut session = Session::new(cfg).unwrap();
        session.set_collect(true);
        for pass in 0..2 {
            for prog in &pattern_programs() {
                let warm = session.run_program(prog).unwrap();
                let cold = run_fresh(cfg, prog);
                let what = format!("pass {pass}, pattern {:?}", prog.output);
                assert_eq!(warm.stats, cold.stats, "{what}: stats diverged");
                assert_eq!(warm.outputs, cold.outputs, "{what}: outputs diverged");
                assert_eq!(warm.preload_cycles, cold.preload_cycles, "{what}: preload");
            }
        }
    }
}

#[test]
fn warm_rearm_across_kind_change_is_bit_identical() {
    // Alternate standard-only and ping-pong configurations on ONE
    // session: every re-arm swaps the level implementation in place and
    // must be indistinguishable from a cold build.
    let standard = HierarchyConfig::builder()
        .offchip(32, 24, 1.0)
        .level(32, 512, 1, 1)
        .level(32, 128, 1, 2)
        .build()
        .unwrap();
    let mut configs = vec![standard];
    configs.extend(db_configs());
    let prog = PatternProgram::shifted_cyclic(0, 96, 16).with_outputs(960);
    let mut session = Session::new(&configs[0]).unwrap();
    session.set_collect(true);
    for (step, cfg) in configs.iter().cycle().take(2 * configs.len()).enumerate() {
        session.rearm(cfg).unwrap();
        let warm = session.run_program(&prog).unwrap();
        let cold = run_fresh(cfg, &prog);
        assert_eq!(warm.stats, cold.stats, "kind-flip step {step}: stats diverged");
        assert_eq!(warm.outputs, cold.outputs, "kind-flip step {step}: outputs diverged");
    }
}

/// The acceptance sweep: two-level space over both kinds, streaming
/// workload (window 256 exceeds the 128-word accelerator-facing level,
/// the §5.2.1 regime where the ping-pong overlap is on the critical
/// path).
fn kinds_space() -> SearchSpace {
    SearchSpace {
        depths: vec![2],
        ram_depths: vec![512, 128],
        word_widths: vec![32],
        level_kinds: vec![KindChoice::Standard, KindChoice::DoubleBuffered],
        try_dual_ported: true,
        protections: vec![memhier::config::Protection::None],
        eval_hz: 100e6,
    }
}

fn streaming_workload() -> PatternProgram {
    PatternProgram::cyclic(0, 256).with_outputs(2_560)
}

fn has_db(p: &DesignPoint) -> bool {
    p.config.levels.iter().any(|l| l.kind == LevelKind::DoubleBuffered)
}

#[test]
fn double_buffered_point_dominates_standard_on_streaming() {
    let points = explore(&kinds_space(), &streaming_workload()).unwrap();
    assert!(points.iter().any(has_db), "sweep must include ping-pong candidates");
    assert!(points.iter().any(|p| !has_db(p)), "sweep must include standard candidates");
    // A ping-pong design on the front strictly dominates a standard
    // design on (area, cycles): overlap throughput below dual-port area.
    let dominated = points.iter().filter(|s| !has_db(s)).any(|s| {
        points
            .iter()
            .any(|d| d.on_front && has_db(d) && d.area < s.area && d.cycles < s.cycles)
    });
    assert!(dominated, "no ping-pong front point dominates a standard design");
}

fn assert_points_identical(a: &[DesignPoint], b: &[DesignPoint], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: point counts differ");
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.config, y.config, "{what}");
        assert_eq!(x.area.to_bits(), y.area.to_bits(), "{what}: area bits");
        assert_eq!(x.power.to_bits(), y.power.to_bits(), "{what}: power bits");
        assert_eq!(x.cycles, y.cycles, "{what}: cycles");
        assert_eq!(x.efficiency.to_bits(), y.efficiency.to_bits(), "{what}: efficiency");
        assert_eq!(x.on_front, y.on_front, "{what}: front membership");
    }
}

#[test]
fn pooled_front_matches_serial_with_kinds_enabled() {
    let space = kinds_space();
    let w = streaming_workload();
    let serial = explore(&space, &w).unwrap();
    assert!(serial.len() >= 8, "space must be non-trivial, got {}", serial.len());
    for threads in [2usize, 4] {
        let pooled = HierarchyPool::new(threads).explore(&space, &w).unwrap();
        assert_points_identical(&serial, &pooled, &format!("pooled threads={threads}"));
    }
}

#[test]
fn halving_front_matches_exhaustive_with_kinds_enabled() {
    let space = kinds_space();
    let w = streaming_workload();
    let schedule = HalvingSchedule::for_workload(&w);
    let exhaustive = explore(&space, &w).unwrap();
    let serial_halved = explore_halving(&space, &w, &schedule).unwrap();
    let ef: Vec<DesignPoint> = exhaustive.iter().filter(|p| p.on_front).cloned().collect();
    let hf: Vec<DesignPoint> =
        serial_halved.points.iter().filter(|p| p.on_front).cloned().collect();
    assert!(!ef.is_empty(), "exhaustive front must be non-trivial");
    assert!(ef.iter().any(has_db), "front must feature a ping-pong design");
    assert_points_identical(&ef, &hf, "halving front vs exhaustive front");
    // Pooled halving equals serial halving, kinds included.
    for threads in [2usize, 4] {
        let pooled = HierarchyPool::new(threads).explore_halving(&space, &w, &schedule).unwrap();
        assert_points_identical(
            &serial_halved.points,
            &pooled.points,
            &format!("pooled halving threads={threads}"),
        );
        assert_eq!(serial_halved.stats, pooled.stats, "halving stats threads={threads}");
    }
}

//! Joint mapping × hierarchy co-exploration invariants.
//!
//! The acceptance contract of `dse::dims` + the joint explorers: the
//! four-axis (area, power, cycles, off-chip reads) Pareto front of the
//! pruned+memoized joint sweep is bitwise-identical to the brute-force
//! nested exhaustive sweep's — serial, pooled, successive-halving, and
//! across worker-process shards — and the analytic traffic model the
//! pruner's fourth axis rests on
//! ([`memhier::mem::FunctionalModel::expected_offchip_reads`]) equals
//! the simulated off-chip read counter exactly across the
//! pattern-family × level-kind × unrolling matrix.

use std::path::PathBuf;

use memhier::dse::{
    explore, explore_joint, explore_joint_halving, explore_joint_halving_pruned,
    explore_joint_naive, explore_joint_sharded, pareto_front, DesignPoint, HalvingSchedule,
    HierarchyPool, JointSpace, KindChoice, SearchSpace, ShardOptions,
};
use memhier::loopnest::LoopOrder;
use memhier::mem::{FunctionalModel, Hierarchy};
use memhier::model::{LayerKind, LayerSpec};

fn layer() -> LayerSpec {
    LayerSpec { idx: 0, kind: LayerKind::Conv, k: 16, c: 8, f: 3, x: 4 }
}

fn space() -> SearchSpace {
    SearchSpace {
        depths: vec![1, 2],
        ram_depths: vec![32, 128],
        word_widths: vec![32],
        level_kinds: vec![KindChoice::Standard, KindChoice::DoubleBuffered],
        try_dual_ported: true,
        protections: vec![memhier::config::Protection::None],
        eval_hz: 100e6,
    }
}

fn joint_space() -> JointSpace {
    JointSpace::new(space(), layer(), 8, &[LoopOrder::ultratrail(), LoopOrder::output_stationary()])
}

fn worker_binary() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_memhier"))
}

/// A stable identity-plus-score key for set comparison of points that
/// may arrive in different (area-sorted) tie orders from independent
/// sweeps.
fn point_key(p: &DesignPoint) -> (u64, u64, u64, u64, String, String) {
    (
        p.area.to_bits(),
        p.power.to_bits(),
        p.cycles,
        p.offchip_reads,
        format!("{:?}", p.mapping),
        format!("{:?}", p.config),
    )
}

/// Ordered bitwise equality of two full point lists.
fn assert_points_identical(a: &[DesignPoint], b: &[DesignPoint], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: point counts differ");
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.config, y.config, "{what}: configs");
        assert_eq!(x.mapping, y.mapping, "{what}: mappings");
        assert_eq!(x.area.to_bits(), y.area.to_bits(), "{what}: area bits");
        assert_eq!(x.power.to_bits(), y.power.to_bits(), "{what}: power bits");
        assert_eq!(x.cycles, y.cycles, "{what}: cycles");
        assert_eq!(x.efficiency.to_bits(), y.efficiency.to_bits(), "{what}: efficiency");
        assert_eq!(x.offchip_reads, y.offchip_reads, "{what}: off-chip reads");
        assert_eq!(x.on_front, y.on_front, "{what}: front membership");
    }
}

/// Ordered bitwise equality of the four-axis fronts of two point lists.
fn assert_fronts_identical(a: &[DesignPoint], b: &[DesignPoint], what: &str) {
    let af: Vec<&DesignPoint> = a.iter().filter(|p| p.on_front).collect();
    let bf: Vec<&DesignPoint> = b.iter().filter(|p| p.on_front).collect();
    assert!(!af.is_empty(), "{what}: front must be non-trivial");
    assert_eq!(af.len(), bf.len(), "{what}: front sizes differ");
    for (x, y) in af.iter().zip(bf.iter()) {
        assert_eq!(x.config, y.config, "{what}: front configs");
        assert_eq!(x.mapping, y.mapping, "{what}: front mappings");
        assert_eq!(x.area.to_bits(), y.area.to_bits(), "{what}: front area bits");
        assert_eq!(x.power.to_bits(), y.power.to_bits(), "{what}: front power bits");
        assert_eq!(x.cycles, y.cycles, "{what}: front cycles");
        assert_eq!(x.offchip_reads, y.offchip_reads, "{what}: front off-chip reads");
    }
}

#[test]
fn joint_front_matches_brute_force_nested_sweep() {
    // The independent oracle: one plain 3-axis `explore` per mapping
    // (the pre-joint API, no joint machinery involved), pooled into one
    // point set and fronted on all four axes by `pareto_front` directly.
    let joint = joint_space();
    let mut brute: Vec<DesignPoint> = Vec::new();
    for (i, w) in joint.workloads.iter().enumerate() {
        for mut p in explore(&joint.space, w).expect("per-mapping explore") {
            p.mapping = Some(joint.mappings[i]);
            brute.push(p);
        }
    }
    let axes: Vec<Vec<f64>> = brute
        .iter()
        .map(|p| vec![p.area, p.power, p.cycles as f64, p.offchip_reads as f64])
        .collect();
    let front_idx = pareto_front(&axes);
    let mut brute_front: Vec<_> = front_idx.iter().map(|&i| point_key(&brute[i])).collect();
    brute_front.sort();
    assert!(!brute_front.is_empty(), "oracle front must be non-trivial");

    let naive = explore_joint_naive(&joint).expect("naive joint sweep");
    let mut naive_front: Vec<_> =
        naive.points.iter().filter(|p| p.on_front).map(point_key).collect();
    naive_front.sort();
    assert_eq!(naive_front, brute_front, "naive joint front != nested exhaustive front");

    let pruned = explore_joint(&joint).expect("pruned joint sweep");
    let mut pruned_front: Vec<_> =
        pruned.points.iter().filter(|p| p.on_front).map(point_key).collect();
    pruned_front.sort();
    assert_eq!(pruned_front, brute_front, "pruned joint front != nested exhaustive front");
}

#[test]
fn joint_explorers_agree_serial_pooled_halving_sharded() {
    let joint = joint_space();
    let naive = explore_joint_naive(&joint).expect("naive joint sweep");

    // Serial pruned+memoized.
    let serial = explore_joint(&joint).expect("serial joint sweep");
    assert_fronts_identical(&naive.points, &serial.points, "serial");

    // Pooled: full bitwise equality with serial, any thread count.
    for threads in [2usize, 3] {
        let pooled = HierarchyPool::new(threads).explore_joint(&joint).expect("pooled joint");
        assert_points_identical(&serial.points, &pooled.points, "pooled");
        assert_eq!(serial.stats, pooled.stats, "pooled stats semantics");
    }

    // Successive halving, plain and bound-pruned.
    let schedule = HalvingSchedule::for_workloads(&joint.workloads);
    let halved = explore_joint_halving(&joint, &schedule).expect("joint halving");
    assert_fronts_identical(&naive.points, &halved.points, "halving");
    let halved_pruned =
        explore_joint_halving_pruned(&joint, &schedule).expect("joint halving pruned");
    assert_fronts_identical(&naive.points, &halved_pruned.points, "halving pruned");

    // Sharded across worker processes: full bitwise equality with the
    // serial halving sweep, plain and pruned.
    for shards in [1usize, 2] {
        let mut opts = ShardOptions::new(shards);
        opts.worker_cmd = Some(worker_binary());
        let sharded = explore_joint_sharded(&joint, &schedule, &opts).expect("sharded joint");
        assert_points_identical(
            &halved.points,
            &sharded.points,
            &format!("sharded shards={shards}"),
        );
        assert_eq!(halved.stats, sharded.stats, "sharded stats shards={shards}");

        opts.prune = true;
        let sharded_pruned =
            explore_joint_sharded(&joint, &schedule, &opts).expect("sharded joint pruned");
        assert_points_identical(
            &halved_pruned.points,
            &sharded_pruned.points,
            &format!("sharded pruned shards={shards}"),
        );
        assert_eq!(
            halved_pruned.stats, sharded_pruned.stats,
            "sharded pruned stats shards={shards}"
        );
    }
}

#[test]
fn joint_stats_ledger_covers_every_candidate() {
    let joint = joint_space();
    let config_count = joint.space.candidates().count();
    let out = explore_joint(&joint).expect("joint sweep");
    let st = out.stats;
    assert_eq!(
        st.enumerated,
        joint.mappings.len() * config_count,
        "enumeration must cover the full cross product"
    );
    assert_eq!(
        st.enumerated,
        st.bound_pruned + st.simulated + st.memo_hits + st.skipped,
        "every candidate is exactly one of pruned/simulated/memoized/skipped"
    );
    assert_eq!(st.simulated, out.points.len() - st.memo_hits, "memoized points are scored too");
    assert_eq!(out.pruned.len(), st.bound_pruned, "pruned points are flagged, never vanished");
    assert!(
        st.memo_hits > 0,
        "the seeded space must exercise cross-mapping memoization"
    );
    for p in &out.pruned {
        assert!(p.mapping.is_some(), "joint pruned points carry their mapping");
    }
    for p in &out.points {
        assert!(p.mapping.is_some(), "joint exact points carry their mapping");
    }
}

#[test]
fn analytic_traffic_matches_simulated_offchip_reads() {
    // The fourth-axis property the pruning-soundness argument rests on:
    // `FunctionalModel::expected_offchip_reads()` equals the simulated
    // off-chip read counter exactly, across every supported mapping's
    // derived pattern family (sequential/strided/cyclic/shifted from
    // both loop orders and all 8-MAC unrollings) × the level-kind and
    // depth matrix of the config space.
    let joint = joint_space();
    let configs: Vec<_> = joint.space.candidates().collect();
    let mut checked = 0usize;
    for w in &joint.workloads {
        for cfg in &configs {
            let Ok(fm) = FunctionalModel::new(cfg, w) else { continue };
            let Ok(mut h) = Hierarchy::new(cfg) else { continue };
            if h.load_program(w).is_err() {
                continue;
            }
            let Ok(r) = h.run() else { continue };
            assert_eq!(
                fm.expected_offchip_reads(),
                r.stats.offchip_reads,
                "analytic traffic diverged: cfg {:?}, workload {:?}",
                cfg,
                w.output
            );
            checked += 1;
        }
    }
    assert!(
        checked >= 100,
        "matrix must exercise a non-trivial share of (mapping, config) pairs, got {checked}"
    );
}

//! Property-based tests (in-tree `testkit`, proptest-style): invariants of
//! the hierarchy over random configurations × pattern programs.
//!
//! Invariants:
//! 1. **Data integrity** — the output stream always equals the functional
//!    model's expected stream (checked internally by the simulator's
//!    verifier; any violation is an `Error::Integrity`).
//! 2. **Termination** — every valid program completes within the
//!    functional model's cycle upper bound.
//! 3. **Conservation** — off-chip reads equal the fetch plan size; level
//!    read/write totals match the compiled program.
//! 4. **Monotonicity** — dual-porting or adding preload never increases
//!    the cycle count.

use memhier::config::HierarchyConfig;
use memhier::mem::{FunctionalModel, Hierarchy};
use memhier::pattern::PatternProgram;
use memhier::testkit::{assert_prop, Dim};

/// Case layout: [d0_exp, d1_exp, l, s_pct, k, outputs_x16, ports0,
/// kind0, kind1] — the kind dims select the level implementation
/// (0 = standard, 1 = double-buffered ping-pong).
const DIMS: &[Dim] = &[
    Dim::new("d0_exp", 5, 10),    // level-0 depth = 2^d0_exp
    Dim::new("d1_exp", 3, 8),     // level-1 depth = 2^d1_exp
    Dim::new("cycle_len", 2, 200),
    Dim::new("shift_pct", 0, 100),
    Dim::new("skip", 0, 3),
    Dim::new("outputs_x16", 1, 40),
    Dim::new("ports0", 1, 2),
    Dim::new("kind0", 0, 1),
    Dim::new("kind1", 0, 1),
];

fn build(case: &[u64]) -> (HierarchyConfig, PatternProgram) {
    let mut b = HierarchyConfig::builder().offchip(32, 24, 1.0);
    b = if case[7] == 1 {
        b.level_double_buffered(32, 1 << case[0])
    } else {
        b.level(32, 1 << case[0], 1, case[6] as u32)
    };
    b = if case[8] == 1 {
        b.level_double_buffered(32, 1 << case[1])
    } else {
        b.level(32, 1 << case[1], 1, 2)
    };
    let cfg = b.build().expect("generated config valid");
    let l = case[2];
    let s = (l * case[3]) / 100;
    let prog = PatternProgram::shifted_cyclic(0, l, s)
        .with_skip_shift(case[4])
        .with_outputs(case[5] * 16);
    (cfg, prog)
}

#[test]
fn prop_integrity_and_termination() {
    assert_prop(0xC0FFEE, DIMS, 60, |case| {
        let (cfg, prog) = build(case);
        let f = FunctionalModel::new(&cfg, &prog).map_err(|e| e.to_string())?;
        let mut h = Hierarchy::new(&cfg).map_err(|e| e.to_string())?;
        h.load_program(&prog).map_err(|e| e.to_string())?;
        // verify=true: the simulator checks every output against the
        // pattern stream and the payload hash.
        let r = h.run().map_err(|e| format!("integrity/deadlock: {e}"))?;
        if r.stats.outputs != f.expected_output_count() {
            return Err(format!(
                "outputs {} != expected {}",
                r.stats.outputs,
                f.expected_output_count()
            ));
        }
        let cyc = r.stats.internal_cycles;
        if cyc > f.cycle_upper_bound() {
            return Err(format!("cycles {cyc} above bound {}", f.cycle_upper_bound()));
        }
        if cyc < f.cycle_lower_bound() {
            return Err(format!("cycles {cyc} below bound {}", f.cycle_lower_bound()));
        }
        Ok(())
    });
}

#[test]
fn prop_offchip_conservation() {
    assert_prop(0xBEEF, DIMS, 40, |case| {
        let (cfg, prog) = build(case);
        let f = FunctionalModel::new(&cfg, &prog).map_err(|e| e.to_string())?;
        let mut h = Hierarchy::new(&cfg).map_err(|e| e.to_string())?;
        h.load_program(&prog).map_err(|e| e.to_string())?;
        let r = h.run().map_err(|e| e.to_string())?;
        if r.stats.offchip_reads != f.expected_offchip_reads() {
            return Err(format!(
                "offchip reads {} != plan {}",
                r.stats.offchip_reads,
                f.expected_offchip_reads()
            ));
        }
        // Per-level totals match the compiled program exactly (a resident
        // level reads more than it writes — that is the data reuse).
        for (i, lu) in f.compiled().levels.iter().enumerate() {
            if r.stats.level_writes[i] != lu.total_writes {
                return Err(format!(
                    "level {i}: {} writes != compiled {}",
                    r.stats.level_writes[i], lu.total_writes
                ));
            }
            if r.stats.level_reads[i] != lu.total_reads {
                return Err(format!(
                    "level {i}: {} reads != compiled {}",
                    r.stats.level_reads[i], lu.total_reads
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_preload_is_monotone() {
    assert_prop(0xFEED, DIMS, 25, |case| {
        let (cfg, prog) = build(case);
        let mut pre_cfg = cfg.clone();
        pre_cfg.preload = true;
        let run = |c: &HierarchyConfig| -> Result<u64, String> {
            let mut h = Hierarchy::new(c).map_err(|e| e.to_string())?;
            h.set_verify(false);
            h.load_program(&prog).map_err(|e| e.to_string())?;
            Ok(h.run().map_err(|e| e.to_string())?.stats.internal_cycles)
        };
        let base = run(&cfg)?;
        let pre = run(&pre_cfg)?;
        // Ping-pong levels may re-phase the swap cadence relative to the
        // cold fill, so allow a small pipeline-phase wobble there; pure
        // standard hierarchies stay strictly monotone.
        let slack = if case[7] == 1 || case[8] == 1 { 8 } else { 0 };
        if pre > base + slack {
            return Err(format!("preload slower: {pre} > {base}"));
        }
        Ok(())
    });
}

#[test]
fn prop_dual_port_is_monotone() {
    assert_prop(0xD00D, DIMS, 25, |case| {
        if case[6] == 2 || case[7] == 1 {
            return Ok(()); // already dual-ported / ports don't apply to ping-pong
        }
        let (cfg_sp, prog) = build(case);
        let mut case_dp = case.to_vec();
        case_dp[6] = 2;
        let (cfg_dp, _) = build(&case_dp);
        let run = |c: &HierarchyConfig| -> Result<u64, String> {
            let mut h = Hierarchy::new(c).map_err(|e| e.to_string())?;
            h.set_verify(false);
            h.load_program(&prog).map_err(|e| e.to_string())?;
            Ok(h.run().map_err(|e| e.to_string())?.stats.internal_cycles)
        };
        let sp = run(&cfg_sp)?;
        let dp = run(&cfg_dp)?;
        // Allow a small pipeline-phase wobble.
        if dp > sp + 8 {
            return Err(format!("dual-ported L0 slower: {dp} > {sp}"));
        }
        Ok(())
    });
}

#[test]
fn prop_efficiency_bounded_by_one() {
    assert_prop(0xACE, DIMS, 30, |case| {
        let (cfg, prog) = build(case);
        let mut h = Hierarchy::new(&cfg).map_err(|e| e.to_string())?;
        h.set_verify(false);
        h.load_program(&prog).map_err(|e| e.to_string())?;
        let r = h.run().map_err(|e| e.to_string())?;
        let eff = r.stats.efficiency();
        if !(0.0..=1.0 + 1e-9).contains(&eff) {
            return Err(format!("efficiency {eff} out of [0,1]"));
        }
        Ok(())
    });
}

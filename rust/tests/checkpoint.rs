//! Checkpoint determinism suite: suspending a simulation into a
//! [`HierarchyCheckpoint`] and resuming it — on the same hierarchy or on
//! a differently-warmed session — must be invisible in the results.
//!
//! 1. **property**: snapshot/restore at arbitrary (seeded-random) cycles,
//!    ping-ponging the run across two warm sessions, is bit-identical to
//!    the uninterrupted run for every §3.2 pattern family × level kind
//!    (standard, dual-ported, double-buffered, OSR, clock ratio,
//!    preload);
//! 2. **exhaustive small case**: suspension at *every* cycle of a small
//!    run restores bit-identically on a fresh hierarchy;
//! 3. **DSE acceptance**: incremental (checkpoint-resumed) halving ==
//!    restart halving == exhaustive sweep, serial and pooled, with level
//!    kinds enabled — and the resume path actually inherits work
//!    (`saved_cycles > 0`).

use memhier::config::HierarchyConfig;
use memhier::dse::{
    explore, explore_halving, explore_halving_restart, DesignPoint, HalvingSchedule,
    HierarchyPool, KindChoice, SearchSpace,
};
use memhier::mem::{BudgetedRun, Hierarchy, RunResult};
use memhier::pattern::PatternProgram;
use memhier::util::{Rng, Xoshiro256};

/// The configuration matrix: standard narrow/wide (+OSR), dual-ported,
/// case-study clock ratio with deep input buffer and preload, and
/// double-buffered (ping-pong) level kinds in both positions.
fn config_matrix() -> Vec<HierarchyConfig> {
    vec![
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level(32, 128, 1, 2)
            .build()
            .unwrap(),
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(128, 128, 1, 1)
            .level(128, 32, 1, 2)
            .osr(256, vec![32])
            .build()
            .unwrap(),
        HierarchyConfig::builder()
            .offchip(32, 24, 4.0)
            .ib_depth(8)
            .level(128, 104, 1, 2)
            .osr(384, vec![384])
            .preload(true)
            .build()
            .unwrap(),
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level_double_buffered(32, 128)
            .build()
            .unwrap(),
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level_double_buffered(32, 64)
            .build()
            .unwrap(),
    ]
}

/// One program per §3.2 pattern family, sized so every config in the
/// matrix accepts it (multiples of the widest packing factor, 4).
fn pattern_programs() -> Vec<PatternProgram> {
    vec![
        PatternProgram::sequential(0, 384),
        PatternProgram::strided(64, 4, 384),
        PatternProgram::cyclic(0, 64).with_outputs(640),
        PatternProgram::cyclic(0, 256).with_outputs(1_024),
        PatternProgram::shifted_cyclic(0, 96, 16).with_outputs(960),
        PatternProgram::shifted_cyclic(0, 64, 32).with_skip_shift(1).with_outputs(768),
    ]
}

/// Whether `prog`'s output total tiles the config's OSR emission width
/// (a widening OSR emits a fixed number of off-chip units per shift, so
/// only tiling totals terminate cleanly).
fn tiles_osr(cfg: &HierarchyConfig, prog: &PatternProgram) -> bool {
    match &cfg.osr {
        Some(o) => {
            let per_emit = (o.shifts[0] / cfg.offchip.data_width) as u64;
            prog.total_outputs % per_emit == 0
        }
        None => true,
    }
}

fn run_fresh(cfg: &HierarchyConfig, prog: &PatternProgram) -> RunResult {
    let mut h = Hierarchy::new(cfg).expect("config valid");
    h.set_collect(true);
    h.load_program(prog).expect("program loads");
    h.run().expect("simulation succeeds")
}

/// Run `prog` chopped into seeded-random budget slices, snapshotting at
/// every suspension and resuming on the *other* of two warm hierarchies
/// (the resume target was last armed for a different program, so every
/// hop exercises rearm + load + restore). Returns the completed result.
fn run_chopped(
    cfg: &HierarchyConfig,
    prog: &PatternProgram,
    rng: &mut Xoshiro256,
) -> RunResult {
    // Shaped like the warm-session suite's sequential program so every
    // matrix config (including the 384-bit-OSR case study) completes it.
    let dirty = PatternProgram::sequential(8, 384);
    let mut cur = Hierarchy::new(cfg).expect("config valid");
    cur.set_collect(true);
    cur.load_program(prog).expect("program loads");
    let mut other = Hierarchy::new(cfg).expect("config valid");
    other.set_collect(true);
    other.load_program(&dirty).expect("dirty program loads");
    other.run().expect("dirty run succeeds");
    loop {
        let delta = 1 + rng.gen_range(257);
        match cur.run_budgeted(delta).expect("budgeted leg succeeds") {
            BudgetedRun::Complete(r) => return r,
            BudgetedRun::Partial { .. } => {
                let ck = cur.snapshot().expect("snapshot mid-run");
                other.load_program(prog).expect("program reloads");
                other.restore(&ck).expect("restore onto warm session");
                std::mem::swap(&mut cur, &mut other);
            }
        }
    }
}

#[test]
fn chopped_run_bit_identical_for_every_pattern_and_kind() {
    let mut rng = Xoshiro256::new(0xC0FFEE);
    for cfg in &config_matrix() {
        for prog in &pattern_programs() {
            if !tiles_osr(cfg, prog) {
                continue;
            }
            let reference = run_fresh(cfg, prog);
            let chopped = run_chopped(cfg, prog, &mut rng);
            let what = format!(
                "cfg {:?}, pattern {:?}",
                cfg.levels.iter().map(|l| (&l.kind, l.ram_depth)).collect::<Vec<_>>(),
                prog.output
            );
            assert_eq!(chopped.stats, reference.stats, "{what}: stats diverged");
            assert_eq!(chopped.outputs, reference.outputs, "{what}: outputs diverged");
        }
    }
}

#[test]
fn suspension_at_every_cycle_restores_exactly() {
    let cfg = HierarchyConfig::builder()
        .offchip(32, 24, 1.0)
        .level(32, 64, 1, 1)
        .level(32, 16, 1, 2)
        .build()
        .unwrap();
    let prog = PatternProgram::shifted_cyclic(0, 16, 4).with_outputs(160);
    let reference = run_fresh(&cfg, &prog);
    let total = reference.stats.internal_cycles;
    assert!(total > 100, "test needs a non-trivial run, got {total}");
    for cut in 1..total {
        let mut h = Hierarchy::new(&cfg).unwrap();
        h.load_program(&prog).unwrap();
        match h.run_budgeted(cut).unwrap() {
            BudgetedRun::Partial { cycles, .. } => assert_eq!(cycles, cut),
            BudgetedRun::Complete(_) => panic!("cut {cut} below total {total} must suspend"),
        }
        let ck = h.snapshot().unwrap();
        let mut resumed = Hierarchy::new(&cfg).unwrap();
        resumed.load_program(&prog).unwrap();
        resumed.restore(&ck).unwrap();
        let r = match resumed.run_budgeted(u64::MAX).unwrap() {
            BudgetedRun::Complete(r) => r,
            other => panic!("resume from cut {cut} must complete, got {other:?}"),
        };
        assert_eq!(r.stats, reference.stats, "cut at cycle {cut} diverged");
    }
}

// ---------- DSE front equality: resume == restart == exhaustive ----------

fn kinds_space() -> SearchSpace {
    SearchSpace {
        depths: vec![1, 2],
        ram_depths: vec![32, 128, 1024],
        word_widths: vec![32],
        level_kinds: vec![KindChoice::Standard, KindChoice::DoubleBuffered],
        try_dual_ported: false,
        protections: vec![memhier::config::Protection::None],
        eval_hz: 100e6,
    }
}

fn assert_points_identical(a: &[DesignPoint], b: &[DesignPoint], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: point counts differ");
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.config, y.config, "{what}");
        assert_eq!(x.area.to_bits(), y.area.to_bits(), "{what}: area bits");
        assert_eq!(x.power.to_bits(), y.power.to_bits(), "{what}: power bits");
        assert_eq!(x.cycles, y.cycles, "{what}: cycles");
        assert_eq!(x.efficiency.to_bits(), y.efficiency.to_bits(), "{what}: efficiency");
        assert_eq!(x.on_front, y.on_front, "{what}: front membership");
    }
}

#[test]
fn incremental_halving_equals_restart_and_exhaustive_serial_and_pooled() {
    let space = kinds_space();
    let w = PatternProgram::cyclic(0, 256).with_outputs(2_560);
    let schedule = HalvingSchedule::for_workload(&w);

    let exhaustive = explore(&space, &w).unwrap();
    let resumed = explore_halving(&space, &w, &schedule).unwrap();
    let restarted = explore_halving_restart(&space, &w, &schedule).unwrap();

    // Identical surviving point sets, restart vs resume.
    assert_points_identical(&resumed.points, &restarted.points, "resume vs restart");
    // Identical Pareto front vs the exhaustive sweep.
    let ef: Vec<DesignPoint> = exhaustive.iter().filter(|p| p.on_front).cloned().collect();
    let rf: Vec<DesignPoint> = resumed.points.iter().filter(|p| p.on_front).cloned().collect();
    assert!(!ef.is_empty(), "exhaustive front must be non-trivial");
    assert_points_identical(&ef, &rf, "resume front vs exhaustive front");
    // The resume path inherits work; the restart path never does.
    assert!(resumed.stats.saved_cycles > 0, "{:?}", resumed.stats);
    assert_eq!(restarted.stats.saved_cycles, 0);

    // Pooled == serial, points and stats (cycle accounting included),
    // for both strategies and several thread counts.
    for threads in [2usize, 4] {
        let pool = HierarchyPool::new(threads);
        let pooled = pool.explore_halving(&space, &w, &schedule).unwrap();
        assert_points_identical(
            &resumed.points,
            &pooled.points,
            &format!("pooled resume threads={threads}"),
        );
        assert_eq!(resumed.stats, pooled.stats, "resume stats threads={threads}");
        let pooled_restart = pool.explore_halving_restart(&space, &w, &schedule).unwrap();
        assert_points_identical(
            &restarted.points,
            &pooled_restart.points,
            &format!("pooled restart threads={threads}"),
        );
        assert_eq!(restarted.stats, pooled_restart.stats, "restart stats threads={threads}");
    }
}

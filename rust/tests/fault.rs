//! Fault-injection acceptance suite: the deterministic campaign layer
//! ([`memhier::sim::fault`]) and the per-level protection contract
//! ([`memhier::config::Protection`]).
//!
//! The invariants pinned here:
//!
//! - **Inertness**: arming an *empty* fault plan is provably inert —
//!   stats, outputs, and mid-run checkpoint bytes are bitwise-identical
//!   to a run that never touched the fault API, across pattern families
//!   and level kinds.
//! - **Protection**: under a single-bit upset, SECDED runs are
//!   bit-identical to fault-free (the upset is corrected), parity runs
//!   are flagged but never silently corrupt, and unprotected runs are
//!   caught by the verify sink.
//! - **Determinism**: a seeded campaign reproduces its
//!   [`FaultCampaignStats`] exactly.
//! - **Timing faults**: a delayed off-chip delivery only stalls; a
//!   dropped delivery hangs or corrupts the run, never passes silently.

use memhier::config::{HierarchyConfig, Protection};
use memhier::mem::{wire, Hierarchy};
use memhier::pattern::PatternProgram;
use memhier::sim::fault::{
    run_campaign, run_campaign_protected, FaultComponent, FaultKind, FaultPlan, FaultSite,
};

/// Two standard SRAM levels (the main.rs default shape).
fn std_cfg(protect: Protection) -> HierarchyConfig {
    HierarchyConfig::builder()
        .offchip(32, 24, 1.0)
        .level(32, 1024, 1, 1)
        .protect(protect)
        .level(32, 128, 1, 2)
        .protect(protect)
        .build()
        .unwrap()
}

/// A double-buffered (ping-pong) last level over a standard first level.
fn pingpong_cfg(protect: Protection) -> HierarchyConfig {
    HierarchyConfig::builder()
        .offchip(32, 24, 1.0)
        .level(32, 1024, 1, 1)
        .protect(protect)
        .level_double_buffered(32, 512)
        .protect(protect)
        .build()
        .unwrap()
}

fn pattern_families() -> Vec<PatternProgram> {
    vec![
        PatternProgram::cyclic(0, 64).with_outputs(640),
        PatternProgram::shifted_cyclic(0, 96, 16).with_outputs(960),
        PatternProgram::shifted_cyclic(0, 64, 32).with_skip_shift(1).with_outputs(768),
    ]
}

/// Run `prog` on a fresh hierarchy, optionally arming an empty plan
/// first; return the Debug rendering of the stats and the output stream.
fn run_once(
    cfg: &HierarchyConfig,
    prog: &PatternProgram,
    arm_empty: bool,
) -> (String, Vec<memhier::sim::OutputWord>) {
    let mut h = Hierarchy::new(cfg).unwrap();
    h.set_collect(true);
    h.load_program(prog).unwrap();
    if arm_empty {
        h.arm_faults(&FaultPlan::new());
    }
    let r = h.run().unwrap();
    if arm_empty {
        let report = h.clear_faults().expect("armed plan must yield a report");
        assert_eq!(report.injected, 0, "an empty plan must not inject");
        assert_eq!(report.vacant, 0, "an empty plan has no events to miss");
    }
    (format!("{:?}", r.stats), r.outputs)
}

/// Mid-run checkpoint bytes, optionally with an empty plan armed.
fn partial_checkpoint_bytes(
    cfg: &HierarchyConfig,
    prog: &PatternProgram,
    arm_empty: bool,
) -> Vec<u8> {
    let mut h = Hierarchy::new(cfg).unwrap();
    h.load_program(prog).unwrap();
    if arm_empty {
        h.arm_faults(&FaultPlan::new());
    }
    let _ = h.run_budgeted(150).unwrap();
    let ck = h.snapshot().unwrap();
    wire::encode_checkpoint(&ck, prog).unwrap()
}

#[test]
fn empty_fault_plan_is_provably_inert() {
    for cfg in [std_cfg(Protection::None), pingpong_cfg(Protection::None)] {
        for prog in pattern_families() {
            let (stats_plain, out_plain) = run_once(&cfg, &prog, false);
            let (stats_armed, out_armed) = run_once(&cfg, &prog, true);
            assert_eq!(stats_plain, stats_armed, "stats must be bitwise-identical");
            assert_eq!(out_plain, out_armed, "output streams must be identical");
            // The injection hook must not perturb checkpointed state
            // either: mid-run snapshots encode to the same bytes.
            let ck_plain = partial_checkpoint_bytes(&cfg, &prog, false);
            let ck_armed = partial_checkpoint_bytes(&cfg, &prog, true);
            assert_eq!(ck_plain, ck_armed, "checkpoint bytes must be identical");
        }
    }
}

/// The single-bit upset used by the protection tests: a flip in a level-1
/// slot that a streaming cyclic workload is guaranteed to re-read.
fn single_flip_plan() -> FaultPlan {
    FaultPlan::new().with(
        200,
        FaultComponent::Level(1),
        FaultSite::Slot { slot: 3, bit: 5, kind: FaultKind::Flip },
    )
}

#[test]
fn secded_corrects_single_bit_flip_bit_identically() {
    let prog = PatternProgram::cyclic(0, 64).with_outputs(640);
    let (stats_free, out_free) = run_once(&std_cfg(Protection::Secded), &prog, false);

    let cfg = std_cfg(Protection::Secded);
    let mut h = Hierarchy::new(&cfg).unwrap();
    h.set_collect(true);
    h.load_program(&prog).unwrap();
    h.arm_faults(&single_flip_plan());
    let r = h.run().expect("SECDED must correct a single-bit flip");
    let report = h.clear_faults().unwrap();
    assert_eq!(report.corrected, 1, "the upset must be corrected, not absorbed");
    assert_eq!(report.injected, 0, "corrected upsets never mutate state");
    assert_eq!(format!("{:?}", r.stats), stats_free, "stats must match fault-free");
    assert_eq!(r.outputs, out_free, "outputs must be bit-identical to fault-free");
}

#[test]
fn parity_flags_single_bit_flip_and_is_never_silent() {
    let prog = PatternProgram::cyclic(0, 64).with_outputs(640);
    let (stats_free, out_free) = run_once(&std_cfg(Protection::Parity), &prog, false);

    let cfg = std_cfg(Protection::Parity);
    let mut h = Hierarchy::new(&cfg).unwrap();
    h.set_collect(true);
    h.load_program(&prog).unwrap();
    h.arm_faults(&single_flip_plan());
    let r = h.run().expect("a detected upset flags the run, it does not corrupt it");
    let report = h.clear_faults().unwrap();
    assert_eq!(report.detected, 1, "parity must detect the single-bit flip");
    assert_eq!(report.injected, 0);
    // Detection means the run is flagged while the data path stays
    // clean — the opposite of silent corruption.
    assert_eq!(format!("{:?}", r.stats), stats_free);
    assert_eq!(r.outputs, out_free);
}

#[test]
fn unprotected_single_bit_flip_is_caught_by_the_verify_sink() {
    let prog = PatternProgram::cyclic(0, 64).with_outputs(640);
    let cfg = std_cfg(Protection::None);
    let mut h = Hierarchy::new(&cfg).unwrap();
    h.set_collect(true);
    h.load_program(&prog).unwrap();
    h.arm_faults(&single_flip_plan());
    let r = h.run();
    let report = h.clear_faults().unwrap();
    assert_eq!(report.injected, 1, "the flip must land in occupied storage");
    assert!(r.is_err(), "a corrupted stored word must fail end-to-end verification");
}

#[test]
fn seeded_campaigns_are_deterministic() {
    let cfg = std_cfg(Protection::None);
    let prog = PatternProgram::cyclic(0, 64).with_outputs(640);
    let a = run_campaign(&cfg, &prog, 0xC0FFEE, 24).unwrap();
    let b = run_campaign(&cfg, &prog, 0xC0FFEE, 24).unwrap();
    assert_eq!(a, b, "a seeded campaign must reproduce its stats exactly");
    assert_eq!(a.total.runs, 24);
    // A different seed schedules a different campaign.
    let c = run_campaign(&cfg, &prog, 0xBEEF, 24).unwrap();
    assert_ne!(a, c, "different seeds must explore different fault sets");
}

#[test]
fn protected_campaigns_have_no_silent_level_corruption() {
    let prog = PatternProgram::cyclic(0, 64).with_outputs(640);
    for protect in [Protection::Parity, Protection::Secded] {
        let stats =
            run_campaign_protected(&std_cfg(Protection::None), &prog, protect, 0xFA117, 24)
                .unwrap();
        for (label, tally) in &stats.per_component {
            if label.starts_with('L') {
                assert_eq!(
                    tally.silent, 0,
                    "{protect:?}: level {label} upsets must never be silent"
                );
            }
        }
    }
}

#[test]
fn delayed_offchip_delivery_only_stalls() {
    let cfg = std_cfg(Protection::None);
    let prog = PatternProgram::cyclic(0, 64).with_outputs(640);
    let baseline = {
        let (_, out) = run_once(&cfg, &prog, false);
        out
    };
    let mut saw_delay = false;
    for at in 1..20u64 {
        let mut h = Hierarchy::new(&cfg).unwrap();
        h.set_collect(true);
        h.set_deadlock_limit(25_000);
        h.load_program(&prog).unwrap();
        h.arm_faults(&FaultPlan::new().with(
            at,
            FaultComponent::OffChip,
            FaultSite::DelayDelivery { extra: 7 },
        ));
        let r = h.run();
        let report = h.clear_faults().unwrap();
        if report.delayed == 1 {
            saw_delay = true;
            let r = r.expect("a delayed delivery must still complete");
            assert_eq!(r.outputs, baseline, "delay is a timing fault, not a data fault");
        }
    }
    assert!(saw_delay, "some cycle in [1,20) must catch a request in flight");
}

#[test]
fn dropped_offchip_delivery_never_passes_silently() {
    let cfg = std_cfg(Protection::None);
    let prog = PatternProgram::cyclic(0, 64).with_outputs(640);
    let mut saw_drop = false;
    for at in 1..20u64 {
        let mut h = Hierarchy::new(&cfg).unwrap();
        h.set_collect(true);
        h.set_deadlock_limit(25_000);
        h.load_program(&prog).unwrap();
        h.arm_faults(&FaultPlan::new().with(at, FaultComponent::OffChip, FaultSite::DropDelivery));
        let r = h.run();
        let report = h.clear_faults().unwrap();
        if report.dropped == 1 {
            saw_drop = true;
            assert!(r.is_err(), "a lost word must hang or corrupt the run, never pass");
        }
    }
    assert!(saw_drop, "some cycle in [1,20) must catch a request in flight");
}

//! Integration tests: hierarchy × pattern × configuration matrix, the
//! §5.2 performance claims end to end, and cross-checks against the
//! functional oracle.

use memhier::config::HierarchyConfig;
use memhier::mem::{FunctionalModel, Hierarchy};
use memhier::pattern::PatternProgram;

fn cfg(levels: &[(u32, u64, u32, u32)], ratio: f64, preload: bool) -> HierarchyConfig {
    let mut b = HierarchyConfig::builder().offchip(32, 24, ratio).preload(preload);
    for &(w, d, banks, ports) in levels {
        b = b.level(w, d, banks, ports);
    }
    b.build().unwrap()
}

/// Differential check against the functional model: output stream and
/// cycle bounds.
fn differential(c: &HierarchyConfig, prog: &PatternProgram) {
    let f = FunctionalModel::new(c, prog).unwrap();
    let mut h = Hierarchy::new(c).unwrap();
    h.set_collect(true);
    h.load_program(prog).unwrap();
    let r = h.run().unwrap();
    let mut sim_units = Vec::new();
    let w_off = c.offchip.data_width;
    for out in &r.outputs {
        for (j, &a) in out.addrs.iter().enumerate() {
            sim_units.push((a, out.word.bits(j as u32 * w_off, w_off)));
        }
    }
    assert_eq!(sim_units, f.expected_units(), "output stream mismatch");
    let cyc = r.stats.internal_cycles;
    assert!(cyc >= f.cycle_lower_bound());
    assert!(cyc <= f.cycle_upper_bound(), "{cyc} > {}", f.cycle_upper_bound());
}

#[test]
fn depth_one_through_five() {
    // Every legal hierarchy depth executes a cyclic pattern correctly.
    for depth in 1..=5usize {
        let levels: Vec<(u32, u64, u32, u32)> = (0..depth)
            .map(|i| {
                let last = i + 1 == depth;
                (32u32, 256 >> i.min(2), 1u32, if last { 2 } else { 1 })
            })
            .collect();
        let c = cfg(&levels, 1.0, false);
        differential(&c, &PatternProgram::cyclic(0, 32).with_outputs(640));
    }
}

#[test]
fn dual_banked_levels_behave_like_dual_ported() {
    // §4.1.2: two single-ported banks emulate a dual-ported module.
    let single = cfg(&[(32, 512, 1, 1), (32, 128, 1, 2)], 1.0, false);
    let banked = cfg(&[(32, 256, 2, 1), (32, 128, 1, 2)], 1.0, false);
    let prog = PatternProgram::shifted_cyclic(0, 64, 32).with_outputs(3_200);
    differential(&banked, &prog);
    let run = |c: &HierarchyConfig| {
        let mut h = Hierarchy::new(c).unwrap();
        h.load_program(&prog).unwrap();
        h.run().unwrap().stats.internal_cycles
    };
    let t_single = run(&single);
    let t_banked = run(&banked);
    assert!(
        t_banked <= t_single + 16,
        "dual banks must not be slower: {t_banked} vs {t_single}"
    );
}

#[test]
fn strided_patterns_supported() {
    let c = cfg(&[(32, 512, 1, 1), (32, 128, 1, 2)], 1.0, false);
    for stride in [2u64, 3, 7] {
        differential(&c, &PatternProgram::strided(10, stride, 700));
    }
}

#[test]
fn skip_shift_matrix() {
    let c = cfg(&[(32, 512, 1, 1), (32, 128, 1, 2)], 1.0, false);
    for k in [0u64, 1, 3] {
        for (l, s) in [(24, 6), (32, 32), (48, 1)] {
            differential(
                &c,
                &PatternProgram::shifted_cyclic(0, l, s).with_skip_shift(k).with_outputs(1_440),
            );
        }
    }
}

#[test]
fn wide_words_with_osr_matrix() {
    for (lvl_w, osr_w, shift) in [(64u32, 64u32, 32u32), (128, 256, 32), (128, 384, 384)] {
        let c = HierarchyConfig::builder()
            .offchip(32, 24, (lvl_w / 32) as f64)
            .level(lvl_w, 128, 1, 1)
            .level(lvl_w, 32, 1, 2)
            .osr(osr_w, vec![shift])
            .build()
            .unwrap();
        let outputs = 12 * 96; // multiple of every grouping in use
        differential(&c, &PatternProgram::cyclic(0, 96).with_outputs(outputs));
    }
}

#[test]
fn clock_ratio_matrix() {
    for ratio in [0.5f64, 1.0, 2.0, 4.0] {
        let c = cfg(&[(32, 512, 1, 1), (32, 128, 1, 2)], ratio, false);
        differential(&c, &PatternProgram::cyclic(0, 64).with_outputs(1_280));
    }
}

#[test]
fn preload_never_slower_and_stream_identical() {
    for (l, s) in [(64u64, 0u64), (96, 32), (128, 128)] {
        let base = cfg(&[(32, 512, 1, 1), (32, 128, 1, 2)], 1.0, false);
        let pre = cfg(&[(32, 512, 1, 1), (32, 128, 1, 2)], 1.0, true);
        let prog = PatternProgram::shifted_cyclic(0, l, s).with_outputs(2_560);
        let run = |c: &HierarchyConfig| {
            let mut h = Hierarchy::new(c).unwrap();
            h.set_collect(true);
            h.load_program(&prog).unwrap();
            h.run().unwrap()
        };
        let a = run(&base);
        let b = run(&pre);
        assert!(
            b.stats.internal_cycles <= a.stats.internal_cycles,
            "preload slower for l={l} s={s}"
        );
        assert_eq!(a.outputs.len(), b.outputs.len());
        for (x, y) in a.outputs.iter().zip(b.outputs.iter()) {
            assert_eq!(x, y, "preload must not change the data stream");
        }
    }
}

#[test]
fn figure5_doubling_claim() {
    // The §5.2.1 claim as an integration test over the real sweep.
    let c = cfg(&[(32, 1024, 1, 1), (32, 128, 1, 2)], 1.0, false);
    let run = |l: u64| {
        let mut h = Hierarchy::new(&c).unwrap();
        h.load_program(&PatternProgram::cyclic(0, l).with_outputs(5_000)).unwrap();
        h.run().unwrap().stats.internal_cycles as f64
    };
    let fits = run(128);
    let spills = run(256);
    assert!(spills / fits > 1.6 && spills / fits < 2.4, "ratio {}", spills / fits);
}

#[test]
fn figure8_one_third_knee() {
    // Optimal while shift < cycle_length/3; degraded beyond (§5.2.3).
    let c = cfg(&[(32, 512, 1, 1), (32, 128, 1, 2)], 1.0, false);
    let eff = |l: u64, s: u64| {
        let mut h = Hierarchy::new(&c).unwrap();
        h.load_program(&PatternProgram::shifted_cyclic(0, l, s).with_outputs(5_016)).unwrap();
        h.run().unwrap().stats.steady_state_efficiency()
    };
    let below = eff(96, 24); // s = l/4 < l/3
    let above = eff(96, 72); // s = 3l/4 > l/3
    assert!(below > 0.95, "below the knee: {below}");
    assert!(above < 0.75, "above the knee: {above}");
}

#[test]
fn deep_hierarchy_streams_through_every_level() {
    // §4.1.2: all data must traverse each level.
    let c = cfg(
        &[(32, 256, 1, 1), (32, 128, 1, 1), (32, 64, 1, 2)],
        1.0,
        false,
    );
    let mut h = Hierarchy::new(&c).unwrap();
    h.load_program(&PatternProgram::cyclic(0, 32).with_outputs(960)).unwrap();
    let r = h.run().unwrap();
    // Every level saw at least the unique word set.
    for (i, &w) in r.stats.level_writes.iter().enumerate() {
        assert!(w >= 32, "level {i} only wrote {w} words");
    }
    assert_eq!(r.stats.outputs, 960);
}

#[test]
fn pattern_switch_via_reprogram() {
    // §5.4: switching DNNs just needs a reset cycle with new settings.
    let c = cfg(&[(32, 512, 1, 1), (32, 128, 1, 2)], 1.0, false);
    let mut h = Hierarchy::new(&c).unwrap();
    h.load_program(&PatternProgram::cyclic(0, 64).with_outputs(640)).unwrap();
    let a = h.run().unwrap();
    assert_eq!(a.stats.outputs, 640);
    // Reprogram with a different pattern; state fully resets.
    h.load_program(&PatternProgram::shifted_cyclic(1_000, 32, 8).with_outputs(320)).unwrap();
    let b = h.run().unwrap();
    assert_eq!(b.stats.outputs, 320);
    assert!(b.stats.internal_cycles < a.stats.internal_cycles);
}

//! Engine-level timing invariants: the stage-based engine refactor must
//! preserve the CDC synchronizer delay, the clock interleaving, and
//! run-to-run determinism of the old monolithic `Hierarchy::run`.

use memhier::config::HierarchyConfig;
use memhier::mem::Hierarchy;
use memhier::pattern::PatternProgram;

fn one_level() -> HierarchyConfig {
    HierarchyConfig::builder()
        .offchip(32, 24, 1.0)
        .level(32, 256, 1, 2)
        .build()
        .unwrap()
}

#[test]
fn cdc_synchronizer_delay_preserved() {
    // The input-buffer handshake costs exactly three internal cycles
    // before the first word is readable (two-flop synchronizer + MCU
    // write), so the first output of a cold single-level hierarchy lands
    // at internal cycle 3: fetch on ext 0/1, sync on int 1/2, write on
    // int 2, read+emit on int 3. A regression here means the engine
    // reordered the CDC step relative to the clock interleaving.
    let mut h = Hierarchy::new(&one_level()).unwrap();
    h.load_program(&PatternProgram::cyclic(0, 64).with_outputs(256)).unwrap();
    let r = h.run().unwrap();
    assert_eq!(r.stats.first_output_cycle, Some(3), "CDC delay changed");
}

#[test]
fn cdc_cadence_is_three_cycles_per_streamed_word() {
    // Streaming (no reuse): every word pays the full buffer_full /
    // reset_buffer round-trip — one word per three internal cycles at
    // equal clocks (§4.1.3, the constant behind the Fig 8 knee).
    let mut h = Hierarchy::new(&one_level()).unwrap();
    h.load_program(&PatternProgram::sequential(0, 300)).unwrap();
    let r = h.run().unwrap();
    let per_word = r.stats.internal_cycles as f64 / 300.0;
    assert!(
        (2.9..3.2).contains(&per_word),
        "expected ~3 cycles/word through the CDC, got {per_word:.3}"
    );
}

#[test]
fn external_domain_interleaving_preserved() {
    // 4:1 external:internal clocks — the engine must step four external
    // edges per internal cycle, exactly as the case study requires.
    let cfg = HierarchyConfig::builder()
        .offchip(32, 24, 4.0)
        .ib_depth(8)
        .level(128, 104, 1, 2)
        .osr(384, vec![384])
        .build()
        .unwrap();
    let mut h = Hierarchy::new(&cfg).unwrap();
    h.load_program(&PatternProgram::sequential(0, 384)).unwrap();
    let r = h.run().unwrap();
    let ratio = r.stats.external_cycles as f64 / r.stats.internal_cycles as f64;
    assert!(
        (3.5..4.5).contains(&ratio),
        "external/internal edge ratio drifted: {ratio:.2}"
    );
}

#[test]
fn runs_are_deterministic() {
    // The engine consumes no ambient state: two identical runs must agree
    // on every counter and every collected output bit.
    let run = || {
        let cfg = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level(32, 128, 1, 2)
            .build()
            .unwrap();
        let mut h = Hierarchy::new(&cfg).unwrap();
        h.set_collect(true);
        h.load_program(&PatternProgram::shifted_cyclic(0, 48, 12).with_outputs(960)).unwrap();
        h.run().unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.stats, b.stats);
    assert_eq!(a.outputs, b.outputs);
}

#[test]
fn micro_stepping_matches_free_run() {
    // step_cycles + run must land on the same totals as one uninterrupted
    // run (the engine keeps all scheduling state across entry points).
    let prog = PatternProgram::shifted_cyclic(0, 32, 8).with_outputs(640);
    let mut a = Hierarchy::new(&one_level()).unwrap();
    a.load_program(&prog).unwrap();
    let free = a.run().unwrap();
    let mut b = Hierarchy::new(&one_level()).unwrap();
    b.load_program(&prog).unwrap();
    b.step_cycles(97).unwrap();
    b.step_cycles(1).unwrap();
    let stepped = b.run().unwrap();
    assert_eq!(free.stats.internal_cycles, stepped.stats.internal_cycles);
    assert_eq!(free.stats.outputs, stepped.stats.outputs);
    assert_eq!(free.stats.offchip_reads, stepped.stats.offchip_reads);
}

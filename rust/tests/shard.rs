//! Sharded-DSE acceptance suite: the multi-process successive-halving
//! coordinator ([`memhier::dse::explore_halving_sharded`]) must produce
//! a Pareto front **bitwise-identical** to the serial sweep — points,
//! front membership, and `HalvingStats` semantics — for any shard
//! count, including a fleet that loses a worker mid-rung.
//!
//! Workers are real OS processes running the `dse-worker` subcommand of
//! the `memhier` binary that Cargo builds for this test run
//! (`CARGO_BIN_EXE_memhier`), so these tests exercise the genuine
//! stdin/stdout frame protocol, not an in-process stand-in.

use std::path::PathBuf;

use memhier::dse::{
    explore, explore_halving, explore_halving_pruned, explore_halving_sharded, DesignPoint,
    HalvingSchedule, KindChoice, SearchSpace, ShardOptions,
};
use memhier::pattern::PatternProgram;

fn worker_binary() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_memhier"))
}

fn space() -> SearchSpace {
    SearchSpace {
        depths: vec![1, 2],
        ram_depths: vec![32, 128, 1024],
        word_widths: vec![32],
        level_kinds: vec![KindChoice::Standard, KindChoice::DoubleBuffered],
        try_dual_ported: false,
        protections: vec![memhier::config::Protection::None],
        eval_hz: 100e6,
    }
}

fn workload() -> PatternProgram {
    PatternProgram::cyclic(0, 256).with_outputs(2_560)
}

fn assert_points_identical(a: &[DesignPoint], b: &[DesignPoint], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: point counts differ");
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.config, y.config, "{what}");
        assert_eq!(x.area.to_bits(), y.area.to_bits(), "{what}: area bits");
        assert_eq!(x.power.to_bits(), y.power.to_bits(), "{what}: power bits");
        assert_eq!(x.cycles, y.cycles, "{what}: cycles");
        assert_eq!(x.efficiency.to_bits(), y.efficiency.to_bits(), "{what}: efficiency");
        assert_eq!(x.offchip_reads, y.offchip_reads, "{what}: off-chip reads");
        assert_eq!(x.mapping, y.mapping, "{what}: mapping");
        assert_eq!(x.on_front, y.on_front, "{what}: front membership");
    }
}

#[test]
fn sharded_front_bitwise_identical_to_serial_and_exhaustive() {
    let space = space();
    let w = workload();
    let schedule = HalvingSchedule::for_workload(&w);
    let serial = explore_halving(&space, &w, &schedule).unwrap();
    let exhaustive = explore(&space, &w).unwrap();

    for shards in [1usize, 2, 3] {
        let mut opts = ShardOptions::new(shards);
        opts.worker_cmd = Some(worker_binary());
        let sharded = explore_halving_sharded(&space, &w, &schedule, &opts).unwrap();

        assert_points_identical(
            &serial.points,
            &sharded.points,
            &format!("sharded shards={shards}"),
        );
        // Stats semantics (evaluation counts, cycle accounting) match;
        // scheduling diagnostics are excluded from equality by design.
        assert_eq!(serial.stats, sharded.stats, "stats shards={shards}");
        assert_eq!(
            sharded.stats.worker_items.len(),
            shards,
            "one utilization counter per worker process"
        );
        let evals: u64 = sharded.stats.worker_items.iter().sum();
        let serial_evals: u64 = serial.stats.worker_items.iter().sum();
        assert_eq!(evals, serial_evals, "shards={shards}: evaluation totals differ");

        // And the sharded front equals the exhaustive sweep's front.
        let ef: Vec<DesignPoint> = exhaustive.iter().filter(|p| p.on_front).cloned().collect();
        let sf: Vec<DesignPoint> =
            sharded.points.iter().filter(|p| p.on_front).cloned().collect();
        assert!(!ef.is_empty(), "exhaustive front must be non-trivial");
        assert_points_identical(&ef, &sf, &format!("front vs exhaustive, shards={shards}"));
    }
}

#[test]
fn killed_worker_costs_only_its_inflight_candidate() {
    let space = space();
    let w = workload();
    let schedule = HalvingSchedule::for_workload(&w);
    let serial = explore_halving(&space, &w, &schedule).unwrap();

    // Kill a worker after the 3rd response of the run: mid-first-rung,
    // with claims outstanding, so the coordinator must respawn the slot
    // and re-dispatch the lost in-flight candidate from the blob store.
    let mut opts = ShardOptions::new(2);
    opts.worker_cmd = Some(worker_binary());
    opts.kill_after = Some(3);
    let sharded = explore_halving_sharded(&space, &w, &schedule, &opts).unwrap();

    assert_points_identical(&serial.points, &sharded.points, "crash recovery");
    assert_eq!(serial.stats, sharded.stats, "crash-recovery stats");
    // The re-dispatched candidate is evaluated exactly once in the
    // merged result, so totals still match the serial count.
    let evals: u64 = sharded.stats.worker_items.iter().sum();
    let serial_evals: u64 = serial.stats.worker_items.iter().sum();
    assert_eq!(evals, serial_evals, "crash recovery must not double-evaluate");
}

#[test]
fn hung_worker_costs_only_its_inflight_candidate() {
    let space = space();
    let w = workload();
    let schedule = HalvingSchedule::for_workload(&w);
    let serial = explore_halving(&space, &w, &schedule).unwrap();

    // The initial slot-0 worker wedges (pipes held open, no response,
    // no EOF) on the request after its 3rd response. Only the
    // per-candidate deadline can notice: the coordinator must kill the
    // wedged process, respawn the slot, and re-dispatch the candidate.
    let mut opts = ShardOptions::new(2);
    opts.worker_cmd = Some(worker_binary());
    opts.hang_after = Some(3);
    opts.deadline = Some(std::time::Duration::from_millis(300));
    let sharded = explore_halving_sharded(&space, &w, &schedule, &opts).unwrap();

    assert_points_identical(&serial.points, &sharded.points, "hang recovery");
    assert_eq!(serial.stats, sharded.stats, "hang-recovery stats");
    let evals: u64 = sharded.stats.worker_items.iter().sum();
    let serial_evals: u64 = serial.stats.worker_items.iter().sum();
    assert_eq!(evals, serial_evals, "hang recovery must not double-evaluate");
    assert!(sharded.stats.respawns >= 1, "the wedged worker must have been replaced");
}

#[test]
fn garbage_frame_worker_costs_only_its_inflight_candidate() {
    let space = space();
    let w = workload();
    let schedule = HalvingSchedule::for_workload(&w);
    let serial = explore_halving(&space, &w, &schedule).unwrap();

    // The initial slot-0 worker answers the request after its 3rd
    // response with one corrupted frame (unknown tag, junk body). The
    // coordinator must treat the stream as untrustworthy: respawn the
    // slot and re-dispatch the candidate, not abort the sweep.
    let mut opts = ShardOptions::new(2);
    opts.worker_cmd = Some(worker_binary());
    opts.garbage_after = Some(3);
    let sharded = explore_halving_sharded(&space, &w, &schedule, &opts).unwrap();

    assert_points_identical(&serial.points, &sharded.points, "garbage-frame recovery");
    assert_eq!(serial.stats, sharded.stats, "garbage-frame stats");
    let evals: u64 = sharded.stats.worker_items.iter().sum();
    let serial_evals: u64 = serial.stats.worker_items.iter().sum();
    assert_eq!(evals, serial_evals, "garbage-frame recovery must not double-evaluate");
    assert!(sharded.stats.respawns >= 1, "the corrupt worker must have been replaced");
}

#[test]
fn blob_store_releases_responded_candidates() {
    let space = space();
    let w = workload();
    let schedule = HalvingSchedule::for_workload(&w);

    let mut opts = ShardOptions::new(2);
    opts.worker_cmd = Some(worker_binary());
    let sharded = explore_halving_sharded(&space, &w, &schedule, &opts).unwrap();

    // On this space candidates suspend across rungs (the minimal-area
    // streaming candidate cannot finish within the last screening budget
    // and cannot be screen-dominated), so blobs do flow through the
    // store across >= 2 passes...
    assert!(sharded.stats.full_runs > 0, "space must leave survivors for the completion pass");
    assert!(
        sharded.stats.blob_bytes_inserted > 0,
        "space must exercise checkpoint suspension"
    );
    assert!(sharded.stats.blob_bytes_peak > 0);
    // ...and the coordinator drops each one the moment its candidate
    // responds, so the peak resident set is strictly below the total
    // ever inserted (candidates suspend across >= 2 rungs, meaning at
    // least one blob was released and replaced rather than accumulated).
    assert!(
        sharded.stats.blob_bytes_peak < sharded.stats.blob_bytes_inserted,
        "peak {} must be below inserted {} — blobs are not being released",
        sharded.stats.blob_bytes_peak,
        sharded.stats.blob_bytes_inserted
    );
}

#[test]
fn sharded_prune_front_bitwise_identical() {
    let space = space();
    let w = workload();
    let schedule = HalvingSchedule::for_workload(&w);
    let serial = explore_halving_pruned(&space, &w, &schedule).unwrap();
    let exhaustive = explore(&space, &w).unwrap();

    for shards in [1usize, 2, 3] {
        let mut opts = ShardOptions::new(shards);
        opts.worker_cmd = Some(worker_binary());
        opts.prune = true;
        let sharded = explore_halving_sharded(&space, &w, &schedule, &opts).unwrap();

        assert_points_identical(
            &serial.points,
            &sharded.points,
            &format!("pruned sharded shards={shards}"),
        );
        assert_eq!(serial.stats, sharded.stats, "pruned stats shards={shards}");

        // Pruned candidates are returned flagged, never silently dropped,
        // and the ledger adds up to the full enumerated space.
        assert_eq!(sharded.pruned.len(), sharded.stats.bound_pruned);
        assert_eq!(serial.pruned.len(), sharded.pruned.len(), "shards={shards}");
        for (a, b) in serial.pruned.iter().zip(sharded.pruned.iter()) {
            assert_eq!(a.config, b.config, "shards={shards}");
            assert_eq!(a.score.area.to_bits(), b.score.area.to_bits());
            assert_eq!(a.score.cycles_lb, b.score.cycles_lb);
            assert_eq!(a.score.cycles_ub, b.score.cycles_ub);
        }
        let s = &sharded.stats;
        assert_eq!(
            s.screen_exact + s.pruned + s.full_runs + s.skipped + s.bound_pruned,
            s.candidates,
            "shards={shards}: accounting must cover every enumerated candidate"
        );

        // The pruned sharded front still equals the exhaustive sweep's.
        let ef: Vec<DesignPoint> = exhaustive.iter().filter(|p| p.on_front).cloned().collect();
        let sf: Vec<DesignPoint> =
            sharded.points.iter().filter(|p| p.on_front).cloned().collect();
        assert!(!ef.is_empty(), "exhaustive front must be non-trivial");
        assert_points_identical(
            &ef,
            &sf,
            &format!("pruned front vs exhaustive, shards={shards}"),
        );
    }
}

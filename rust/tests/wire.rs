//! Wire-format acceptance suite for [`memhier::mem::wire`]:
//!
//! 1. **property**: runs chopped into seeded-random budget slices, with
//!    every suspension round-tripped *through the wire format* before
//!    resuming, are bit-identical to the uninterrupted run for every
//!    §3.2 pattern family × level kind (standard, wide + OSR, clock
//!    ratio + preload, double-buffered) — and the decoded checkpoint
//!    compares equal to the one that was encoded;
//! 2. **adversarial input**: every strict prefix of a valid encoding
//!    and every single-byte corruption either decodes to a checked
//!    value or returns a checked error — never a panic — and bad
//!    magic / unknown versions / mismatched workloads are rejected
//!    with the documented error kinds.

use memhier::config::HierarchyConfig;
use memhier::mem::{decode_checkpoint, encode_checkpoint, BudgetedRun, Hierarchy, RunResult};
use memhier::pattern::PatternProgram;
use memhier::util::{Rng, Xoshiro256};
use memhier::Error;

/// The level-kind × clock-ratio configuration matrix (mirrors the
/// checkpoint suite): standard narrow/wide (+OSR), case-study clock
/// ratio with deep input buffer and preload, and double-buffered levels.
fn config_matrix() -> Vec<HierarchyConfig> {
    vec![
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level(32, 128, 1, 2)
            .build()
            .unwrap(),
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(128, 128, 1, 1)
            .level(128, 32, 1, 2)
            .osr(256, vec![32])
            .build()
            .unwrap(),
        HierarchyConfig::builder()
            .offchip(32, 24, 4.0)
            .ib_depth(8)
            .level(128, 104, 1, 2)
            .osr(384, vec![384])
            .preload(true)
            .build()
            .unwrap(),
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level_double_buffered(32, 128)
            .build()
            .unwrap(),
    ]
}

/// One program per §3.2 pattern family, sized so every config in the
/// matrix accepts it.
fn pattern_programs() -> Vec<PatternProgram> {
    vec![
        PatternProgram::sequential(0, 384),
        PatternProgram::strided(64, 4, 384),
        PatternProgram::cyclic(0, 64).with_outputs(640),
        PatternProgram::shifted_cyclic(0, 96, 16).with_outputs(960),
        PatternProgram::shifted_cyclic(0, 64, 32).with_skip_shift(1).with_outputs(768),
    ]
}

/// Whether `prog`'s output total tiles the config's OSR emission width.
fn tiles_osr(cfg: &HierarchyConfig, prog: &PatternProgram) -> bool {
    match &cfg.osr {
        Some(o) => {
            let per_emit = (o.shifts[0] / cfg.offchip.data_width) as u64;
            prog.total_outputs % per_emit == 0
        }
        None => true,
    }
}

fn run_fresh(cfg: &HierarchyConfig, prog: &PatternProgram) -> RunResult {
    let mut h = Hierarchy::new(cfg).expect("config valid");
    h.set_collect(true);
    h.load_program(prog).expect("program loads");
    h.run().expect("simulation succeeds")
}

/// Run `prog` in seeded-random budget slices; every suspension is
/// encoded to wire bytes, decoded back, compared to the original
/// checkpoint, and resumed on a **fresh** hierarchy built from the
/// *decoded* configuration — so the bytes, not the in-process objects,
/// carry all state across each hop.
fn run_over_wire(
    cfg: &HierarchyConfig,
    prog: &PatternProgram,
    rng: &mut Xoshiro256,
) -> RunResult {
    let mut cur = Hierarchy::new(cfg).expect("config valid");
    cur.set_collect(true);
    cur.load_program(prog).expect("program loads");
    loop {
        let delta = 1 + rng.gen_range(257);
        match cur.run_budgeted(delta).expect("budgeted leg succeeds") {
            BudgetedRun::Complete(r) => return r,
            BudgetedRun::Partial { .. } => {
                let ck = cur.snapshot().expect("snapshot mid-run");
                let bytes = encode_checkpoint(&ck, prog).expect("encode succeeds");
                let (decoded, workload) = decode_checkpoint(&bytes).expect("decode succeeds");
                assert_eq!(decoded, ck, "decoded checkpoint differs from encoded");
                assert_eq!(&workload, prog, "decoded workload differs");
                let mut next = Hierarchy::new(decoded.config()).expect("decoded config valid");
                next.set_collect(true);
                next.load_program(&workload).expect("decoded workload loads");
                next.restore(&decoded).expect("restore from wire");
                cur = next;
            }
        }
    }
}

#[test]
fn wire_roundtrip_bit_identical_for_every_pattern_and_kind() {
    let mut rng = Xoshiro256::new(0xD15C);
    for cfg in &config_matrix() {
        for prog in &pattern_programs() {
            if !tiles_osr(cfg, prog) {
                continue;
            }
            let reference = run_fresh(cfg, prog);
            let wired = run_over_wire(cfg, prog, &mut rng);
            let what = format!(
                "cfg {:?}, pattern {:?}",
                cfg.levels.iter().map(|l| (&l.kind, l.ram_depth)).collect::<Vec<_>>(),
                prog.output
            );
            assert_eq!(wired.stats, reference.stats, "{what}: stats diverged");
            assert_eq!(wired.outputs, reference.outputs, "{what}: outputs diverged");
        }
    }
}

/// Produce a small valid encoding for the adversarial tests.
fn small_encoding() -> (Vec<u8>, HierarchyConfig, PatternProgram) {
    let cfg = HierarchyConfig::builder()
        .offchip(32, 24, 1.0)
        .level(32, 64, 1, 1)
        .level(32, 16, 1, 2)
        .build()
        .unwrap();
    let prog = PatternProgram::shifted_cyclic(0, 16, 4).with_outputs(160);
    let mut h = Hierarchy::new(&cfg).unwrap();
    h.load_program(&prog).unwrap();
    match h.run_budgeted(64).unwrap() {
        BudgetedRun::Partial { .. } => {}
        BudgetedRun::Complete(_) => panic!("budget must suspend mid-run"),
    }
    let ck = h.snapshot().unwrap();
    let bytes = encode_checkpoint(&ck, &prog).unwrap();
    (bytes, cfg, prog)
}

#[test]
fn every_truncation_is_a_checked_error() {
    let (bytes, _, _) = small_encoding();
    assert!(bytes.len() > 64, "encoding suspiciously small: {}", bytes.len());
    for cut in 0..bytes.len() {
        let err = decode_checkpoint(&bytes[..cut]);
        assert!(err.is_err(), "strict prefix of {cut} bytes decoded successfully");
    }
}

#[test]
fn single_byte_corruption_never_panics() {
    let (bytes, cfg, prog) = small_encoding();
    let mut rejected = 0usize;
    for i in 0..bytes.len() {
        for flip in [0x01u8, 0xFF] {
            let mut evil = bytes.clone();
            evil[i] ^= flip;
            match decode_checkpoint(&evil) {
                Err(_) => rejected += 1,
                Ok((ck, workload)) => {
                    // A flip in unvalidated payload (counters, words) can
                    // still decode; the checkpoint must stay structurally
                    // usable — restore may reject it, but nothing panics.
                    let mut h = Hierarchy::new(ck.config()).unwrap();
                    if h.load_program(&workload).is_ok() {
                        let _ = h.restore(&ck);
                    }
                }
            }
        }
    }
    // The envelope is validated, so flips there are rejected outright
    // (payload flips may legitimately decode — counters and memory
    // words are data, not structure).
    assert!(rejected > 0, "no corruption was rejected");
    for i in 0..6 {
        for flip in [0x01u8, 0xFF] {
            let mut evil = bytes.clone();
            evil[i] ^= flip;
            assert!(decode_checkpoint(&evil).is_err(), "magic/version flip at {i} accepted");
        }
    }
    // Sanity: the pristine bytes still decode after all that.
    let (ck, workload) = decode_checkpoint(&bytes).unwrap();
    assert_eq!(ck.config(), &cfg);
    assert_eq!(workload, prog);
}

#[test]
fn mismatched_workload_and_foreign_config_are_rejected() {
    let (bytes, _, prog) = small_encoding();
    let (ck, _) = decode_checkpoint(&bytes).unwrap();

    // Encoding against a program that is not the checkpoint's bound
    // program fails up front.
    let other = PatternProgram::cyclic(0, 32).with_outputs(320);
    let err = encode_checkpoint(&ck, &other).unwrap_err();
    assert!(matches!(err, Error::Config(_)), "mismatched workload: {err}");

    // A decoded checkpoint keyed to config A cannot restore onto a
    // hierarchy built for config B.
    let foreign = HierarchyConfig::builder()
        .offchip(32, 24, 1.0)
        .level(32, 128, 1, 1)
        .build()
        .unwrap();
    let mut h = Hierarchy::new(&foreign).unwrap();
    h.load_program(&prog).unwrap();
    assert!(h.restore(&ck).is_err(), "foreign-config restore must fail");
}

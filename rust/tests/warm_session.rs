//! Warm-session determinism: a hierarchy that is re-armed and reused
//! (the session layer) must be observationally identical to a freshly
//! constructed one — bit-identical `SimStats` and output words for every
//! pattern family, across configuration changes, and through the DSE
//! paths built on top of it.

use memhier::config::HierarchyConfig;
use memhier::dse::{explore, explore_halving, DesignPoint, HalvingSchedule, KindChoice, SearchSpace};
use memhier::mem::{Hierarchy, RunResult};
use memhier::pattern::PatternProgram;
use memhier::sim::batch::Session;

/// The configuration matrix the determinism tests sweep: narrow, wide +
/// OSR (packing and splitting), single-level, deep FIFO input buffer,
/// and preloading.
fn config_matrix() -> Vec<HierarchyConfig> {
    vec![
        // Two-level 32-bit (the Fig 5 shape).
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level(32, 128, 1, 2)
            .build()
            .unwrap(),
        // Wide levels + narrowing OSR (the Fig 6 shape).
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(128, 128, 1, 1)
            .level(128, 32, 1, 2)
            .osr(256, vec![32])
            .build()
            .unwrap(),
        // Single level, dual-ported.
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 256, 1, 2)
            .build()
            .unwrap(),
        // Case-study shape: 4x external clock, deep input buffer,
        // widening OSR, preload.
        HierarchyConfig::builder()
            .offchip(32, 24, 4.0)
            .ib_depth(8)
            .level(128, 104, 1, 2)
            .osr(384, vec![384])
            .preload(true)
            .build()
            .unwrap(),
        // Ping-pong (double-buffered) last level behind a standard level.
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level_double_buffered(32, 128)
            .build()
            .unwrap(),
        // Single ping-pong level (pure streaming hierarchy).
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level_double_buffered(32, 64)
            .build()
            .unwrap(),
    ]
}

/// One program per §3.2 pattern family, sized so every config in the
/// matrix accepts it (multiples of the widest packing factor, 4).
fn pattern_programs() -> Vec<PatternProgram> {
    vec![
        // Sequential / linear (no reuse).
        PatternProgram::sequential(0, 384),
        // Strided.
        PatternProgram::strided(64, 4, 384),
        // Pure cyclic, window fits everywhere.
        PatternProgram::cyclic(0, 64).with_outputs(640),
        // Cyclic, window larger than some levels (replacement).
        PatternProgram::cyclic(0, 256).with_outputs(1_024),
        // Shifted cyclic (the workhorse).
        PatternProgram::shifted_cyclic(0, 96, 16).with_outputs(960),
        // Shifted cyclic with skip_shift (shift applied every 2nd cycle).
        PatternProgram::shifted_cyclic(0, 64, 32).with_skip_shift(1).with_outputs(768),
    ]
}

fn run_fresh(cfg: &HierarchyConfig, prog: &PatternProgram) -> RunResult {
    let mut h = Hierarchy::new(cfg).expect("config valid");
    h.set_collect(true);
    h.load_program(prog).expect("program loads");
    h.run().expect("simulation succeeds")
}

fn assert_runs_identical(warm: &RunResult, cold: &RunResult, what: &str) {
    assert_eq!(warm.stats, cold.stats, "{what}: stats diverged");
    assert_eq!(warm.preload_cycles, cold.preload_cycles, "{what}: preload diverged");
    assert_eq!(warm.outputs, cold.outputs, "{what}: output words diverged");
}

#[test]
fn warm_session_bit_identical_for_every_pattern_kind() {
    for cfg in &config_matrix() {
        let mut session = Session::new(cfg).unwrap();
        session.set_collect(true);
        // Run the whole battery twice back-to-back: the second pass hits a
        // session warmed by *every* pattern kind, not just its own.
        for pass in 0..2 {
            for prog in &pattern_programs() {
                let warm = session.run_program(prog).unwrap();
                let cold = run_fresh(cfg, prog);
                let what = format!(
                    "pass {pass}, cfg {:?}, pattern {:?}",
                    cfg.levels.iter().map(|l| l.ram_depth).collect::<Vec<_>>(),
                    prog.output
                );
                assert_runs_identical(&warm, &cold, &what);
            }
        }
        assert_eq!(session.programs_run(), 2 * pattern_programs().len() as u64);
    }
}

#[test]
fn warm_session_bit_identical_across_reconfiguration() {
    // One session re-armed through the whole config matrix, twice, with a
    // pattern run under each config — against fresh hierarchies.
    let configs = config_matrix();
    let prog = PatternProgram::cyclic(0, 64).with_outputs(640);
    let mut session = Session::new(&configs[0]).unwrap();
    session.set_collect(true);
    for (step, cfg) in configs.iter().cycle().take(2 * configs.len()).enumerate() {
        session.rearm(cfg).unwrap();
        let warm = session.run_program(&prog).unwrap();
        let cold = run_fresh(cfg, &prog);
        assert_runs_identical(&warm, &cold, &format!("reconfiguration step {step}"));
    }
}

fn assert_points_identical(a: &[DesignPoint], b: &[DesignPoint], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: point counts differ");
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.config, y.config, "{what}");
        assert_eq!(x.area.to_bits(), y.area.to_bits(), "{what}: area bits");
        assert_eq!(x.power.to_bits(), y.power.to_bits(), "{what}: power bits");
        assert_eq!(x.cycles, y.cycles, "{what}: cycles");
        assert_eq!(x.efficiency.to_bits(), y.efficiency.to_bits(), "{what}: efficiency bits");
        assert_eq!(x.on_front, y.on_front, "{what}: front membership");
    }
}

#[test]
fn successive_halving_front_equals_exhaustive_front() {
    // The satellite guarantee: on a seeded search space the halving
    // sweep's Pareto front is bitwise-identical to the exhaustive one
    // (survivors are re-scored exactly; pruned candidates are dominated).
    let space = SearchSpace {
        depths: vec![1, 2],
        ram_depths: vec![32, 128, 1024],
        word_widths: vec![32],
        level_kinds: vec![KindChoice::Standard],
        try_dual_ported: false,
        protections: vec![memhier::config::Protection::None],
        eval_hz: 100e6,
    };
    let workload = PatternProgram::cyclic(0, 256).with_outputs(2_560);
    let exhaustive = explore(&space, &workload).unwrap();
    let halved =
        explore_halving(&space, &workload, &HalvingSchedule::for_workload(&workload)).unwrap();
    let ef: Vec<DesignPoint> = exhaustive.iter().filter(|p| p.on_front).cloned().collect();
    let hf: Vec<DesignPoint> = halved.points.iter().filter(|p| p.on_front).cloned().collect();
    assert!(!ef.is_empty(), "exhaustive front must be non-trivial");
    assert_points_identical(&ef, &hf, "halving front vs exhaustive front");
    // And the halving run must actually have saved work on this space.
    assert!(
        halved.stats.pruned > 0,
        "expected pruning on the seeded space: {:?}",
        halved.stats
    );
    assert!(
        halved.stats.full_runs < halved.stats.candidates,
        "some candidates should resolve without a dedicated full run: {:?}",
        halved.stats
    );
}

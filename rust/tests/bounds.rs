//! Analytical-bound property suite: the admissible cycle bounds and
//! closed-form activity counts that the DSE bound-and-prune front end
//! ([`memhier::dse::bound`]) rests on must hold against the
//! cycle-accurate simulator across the full §3.2 pattern-family ×
//! level-kind × clock-ratio matrix — the same matrix the fast-forward
//! differential suite (`tests/engine_ff.rs`) polices.
//!
//! Three properties, in increasing strength:
//!
//! 1. `cycle_lower_bound() <= simulated internal_cycles <=
//!    cycle_upper_bound()` — admissibility; the pruner's interval
//!    dominance is only sound if the true cycle count lands inside the
//!    bracket.
//! 2. Every *event* counter in [`FunctionalModel::activity_stats`]
//!    (outputs, off-chip reads, per-level reads/writes, CDC transfers,
//!    OSR shifts) equals the simulated counter exactly — the power
//!    bounds are exact-counts-over-bounded-time, not estimates.
//! 3. The run's true average power is bracketed by `run_power` evaluated
//!    at the two cycle bounds (power is weakly decreasing in run time at
//!    fixed event counts).

use memhier::config::HierarchyConfig;
use memhier::cost::run_power;
use memhier::mem::{FunctionalModel, Hierarchy, RunResult};
use memhier::pattern::PatternProgram;

const EVAL_HZ: f64 = 100e6;

/// The fast-forward suite's configuration matrix: standard narrow/wide
/// (+OSR), the 4x-clock deep-input-buffer preload case study, ping-pong
/// kinds, and the stall-heavy latency/ratio shapes.
fn config_matrix() -> Vec<HierarchyConfig> {
    vec![
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level(32, 128, 1, 2)
            .build()
            .unwrap(),
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(128, 128, 1, 1)
            .level(128, 32, 1, 2)
            .osr(256, vec![32])
            .build()
            .unwrap(),
        HierarchyConfig::builder()
            .offchip(32, 24, 4.0)
            .ib_depth(8)
            .level(128, 104, 1, 2)
            .osr(384, vec![384])
            .preload(true)
            .build()
            .unwrap(),
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level_double_buffered(32, 128)
            .build()
            .unwrap(),
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level_double_buffered(32, 64)
            .build()
            .unwrap(),
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .offchip_latency(16)
            .level(32, 64, 1, 1)
            .level(32, 16, 1, 2)
            .build()
            .unwrap(),
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .offchip_latency(16)
            .level(32, 64, 1, 1)
            .level_double_buffered(32, 16)
            .build()
            .unwrap(),
        HierarchyConfig::builder()
            .offchip(32, 24, 0.5)
            .offchip_latency(8)
            .level(32, 128, 1, 1)
            .build()
            .unwrap(),
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .offchip_latency(16)
            .ib_depth(2)
            .level(32, 256, 1, 1)
            .preload(true)
            .build()
            .unwrap(),
    ]
}

/// One program per §3.2 pattern family, sized so every config in the
/// matrix accepts it.
fn pattern_programs() -> Vec<PatternProgram> {
    vec![
        PatternProgram::sequential(0, 384),
        PatternProgram::strided(64, 4, 384),
        PatternProgram::cyclic(0, 64).with_outputs(640),
        PatternProgram::cyclic(0, 256).with_outputs(1_024),
        PatternProgram::shifted_cyclic(0, 96, 16).with_outputs(960),
        PatternProgram::shifted_cyclic(0, 64, 32).with_skip_shift(1).with_outputs(768),
    ]
}

/// Whether `prog`'s output total tiles the config's OSR emission width.
fn tiles_osr(cfg: &HierarchyConfig, prog: &PatternProgram) -> bool {
    match &cfg.osr {
        Some(o) => {
            let per_emit = (o.shifts[0] / cfg.offchip.data_width) as u64;
            prog.total_outputs % per_emit == 0
        }
        None => true,
    }
}

fn run(cfg: &HierarchyConfig, prog: &PatternProgram) -> RunResult {
    let mut h = Hierarchy::new(cfg).expect("config valid");
    h.load_program(prog).expect("program loads");
    h.run().expect("simulation succeeds")
}

fn describe(cfg: &HierarchyConfig, prog: &PatternProgram) -> String {
    format!(
        "cfg {:?} latency {} ib {} ratio {}:{}, pattern {:?}",
        cfg.levels.iter().map(|l| (&l.kind, l.ram_depth)).collect::<Vec<_>>(),
        cfg.offchip.latency,
        cfg.offchip.ib_depth,
        cfg.offchip.external_hz,
        cfg.offchip.internal_hz,
        prog.output
    )
}

/// Walk the matrix once, handing each admissible (config, program) pair
/// plus its functional model and completed run to `check`.
fn for_matrix(mut check: impl FnMut(&HierarchyConfig, &FunctionalModel, &RunResult, &str)) {
    for cfg in &config_matrix() {
        for prog in &pattern_programs() {
            if !tiles_osr(cfg, prog) {
                continue;
            }
            let what = describe(cfg, prog);
            let fm = FunctionalModel::new(cfg, prog).expect("model builds");
            let r = run(cfg, prog);
            check(cfg, &fm, &r, &what);
        }
    }
}

#[test]
fn cycle_bounds_bracket_simulation_for_full_matrix() {
    for_matrix(|_cfg, fm, r, what| {
        let lb = fm.cycle_lower_bound();
        let ub = fm.cycle_upper_bound();
        let cycles = r.stats.internal_cycles;
        assert!(lb >= 1, "{what}: lower bound must be positive");
        assert!(
            lb <= cycles,
            "{what}: lower bound {lb} exceeds simulated {cycles}"
        );
        assert!(
            cycles <= ub,
            "{what}: simulated {cycles} exceeds upper bound {ub}"
        );
    });
}

#[test]
fn activity_counts_match_simulation_exactly_for_full_matrix() {
    for_matrix(|_cfg, fm, r, what| {
        let a = fm.activity_stats(r.stats.internal_cycles);
        assert_eq!(a.outputs, r.stats.outputs, "{what}: outputs");
        assert_eq!(a.offchip_reads, r.stats.offchip_reads, "{what}: offchip reads");
        assert_eq!(a.level_writes, r.stats.level_writes, "{what}: level writes");
        assert_eq!(a.level_reads, r.stats.level_reads, "{what}: level reads");
        assert_eq!(a.cdc_transfers, r.stats.cdc_transfers, "{what}: cdc transfers");
        assert_eq!(a.osr_shifts, r.stats.osr_shifts, "{what}: osr shifts");
    });
}

#[test]
fn power_bounds_bracket_simulation_for_full_matrix() {
    for_matrix(|cfg, fm, r, what| {
        let lb = fm.cycle_lower_bound();
        let ub = fm.cycle_upper_bound();
        // Exact counts over the cycle lower bound = worst-case power;
        // over the upper bound = best-case.
        let power_ub = run_power(cfg, &fm.activity_stats(lb), EVAL_HZ).total;
        let power_lb = run_power(cfg, &fm.activity_stats(ub), EVAL_HZ).total;
        let real = run_power(cfg, &r.stats, EVAL_HZ).total;
        assert!(
            power_lb <= real && real <= power_ub,
            "{what}: run power {real} outside [{power_lb}, {power_ub}]"
        );
        assert!(power_lb > 0.0, "{what}: power lower bound must be positive");
    });
}

//! Runtime + coordinator integration: load the real AOT artifacts and run
//! inference. These tests require `make artifacts` to have run; they skip
//! (with a loud message) if the artifacts are absent so `cargo test` stays
//! runnable from a pristine checkout.

use memhier::coordinator::{synth_request, KwsServer, ServerConfig, MFCC_BINS, MFCC_FRAMES, N_CLASSES};
use memhier::runtime::Runtime;
use std::path::Path;

fn artifacts_present() -> bool {
    if Path::new("artifacts/tcresnet.hlo.txt").exists() {
        true
    } else {
        eprintln!("SKIP: artifacts/tcresnet.hlo.txt missing — run `make artifacts`");
        false
    }
}

#[test]
fn load_and_execute_tcresnet() {
    if !artifacts_present() {
        return;
    }
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let model = rt.load_hlo_text(Path::new("artifacts/tcresnet.hlo.txt")).expect("compile");
    let x = vec![0.1f32; MFCC_BINS * MFCC_FRAMES];
    let outs = rt
        .run_f32(&model, &[(x, vec![1, MFCC_BINS as i64, MFCC_FRAMES as i64])])
        .expect("execute");
    assert_eq!(outs.len(), 2, "logits + aux head");
    assert_eq!(outs[0].len(), N_CLASSES);
    assert_eq!(outs[1].len(), 4);
    assert!(outs[0].iter().all(|v| v.is_finite()));
}

#[test]
fn execution_is_deterministic() {
    if !artifacts_present() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let model = rt.load_hlo_text(Path::new("artifacts/tcresnet.hlo.txt")).unwrap();
    let r = synth_request(3);
    let input = vec![(r.features.clone(), vec![1, MFCC_BINS as i64, MFCC_FRAMES as i64])];
    let a = rt.run_f32(&model, &input).unwrap();
    let b = rt.run_f32(&model, &input).unwrap();
    assert_eq!(a, b);
}

#[test]
fn conv_kernel_artifact_matches_shapes() {
    if !artifacts_present() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let model = rt.load_hlo_text(Path::new("artifacts/conv1d.hlo.txt")).expect("kernel artifact");
    let x = vec![0.5f32; 40 * 100];
    let w = vec![0.01f32; 16 * 40 * 3];
    let outs = rt
        .run_f32(&model, &[(x, vec![40, 100]), (w, vec![16, 40, 3])])
        .expect("execute kernel");
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].len(), 16 * 98);
    // Constant input x constant weights: every output equals C*F*x*w.
    let expect = 40.0 * 3.0 * 0.5 * 0.01;
    for v in &outs[0] {
        assert!((v - expect).abs() < 1e-4, "{v} vs {expect}");
    }
}

#[test]
fn coordinator_serves_batches() {
    if !artifacts_present() {
        return;
    }
    let mut server = KwsServer::new(
        Path::new("artifacts/tcresnet.hlo.txt"),
        ServerConfig { max_batch: 4, ..ServerConfig::default() },
    )
    .expect("server");
    let requests: Vec<_> = (0..10u64).map(synth_request).collect();
    let results = server.serve_stream(requests).expect("serve");
    assert_eq!(results.len(), 10);
    // Ids preserved, classes in range, co-simulation attached.
    for (i, r) in results.iter().enumerate() {
        assert_eq!(r.id, i as u64);
        assert!(r.class < N_CLASSES);
        let cycles = r.accel_cycles.expect("cosim on");
        assert!(cycles > 10_000 && cycles < 40_000, "plausible cycle count: {cycles}");
    }
    assert_eq!(server.stats().served, 10);
    assert!(server.stats().batches >= 3);
}

#[test]
fn coordinator_deterministic_logits() {
    if !artifacts_present() {
        return;
    }
    let mut server = KwsServer::new(
        Path::new("artifacts/tcresnet.hlo.txt"),
        ServerConfig {
            max_batch: 2,
            cosim_weights: false,
            preload: false,
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let a = server.serve_batch(&[synth_request(7)]).unwrap();
    let b = server.serve_batch(&[synth_request(7)]).unwrap();
    assert_eq!(a[0].logits, b[0].logits);
    assert_eq!(a[0].class, b[0].class);
}

//! Edge cases and failure injection: the checks a hardware verification
//! plan would call corner coverage.

use memhier::config::HierarchyConfig;
use memhier::mem::Hierarchy;
use memhier::pattern::PatternProgram;
use memhier::Error;

fn two_level() -> HierarchyConfig {
    HierarchyConfig::builder()
        .offchip(32, 24, 1.0)
        .level(32, 256, 1, 1)
        .level(32, 64, 1, 2)
        .build()
        .unwrap()
}

// ---------- failure injection ----------

#[test]
fn bit_flip_in_resident_level_is_caught() {
    let mut h = Hierarchy::new(&two_level()).unwrap();
    h.load_program(&PatternProgram::cyclic(0, 32).with_outputs(640)).unwrap();
    // Let the window fill, then corrupt a stored word.
    h.step_cycles(120).unwrap();
    assert!(h.inject_bit_flip(1, 5, 7), "slot 5 should be occupied");
    let err = h.run().unwrap_err();
    match err {
        Error::Integrity { msg, .. } => {
            assert!(msg.contains("payload corruption"), "{msg}")
        }
        other => panic!("expected integrity error, got {other}"),
    }
}

#[test]
fn bit_flip_detected_across_packing_and_osr() {
    // Corruption in a 128-bit packed word must be attributed through the
    // OSR unpacking.
    let cfg = HierarchyConfig::builder()
        .offchip(32, 24, 4.0)
        .level(128, 64, 1, 1)
        .level(128, 16, 1, 2)
        .osr(256, vec![32])
        .build()
        .unwrap();
    let mut h = Hierarchy::new(&cfg).unwrap();
    h.load_program(&PatternProgram::cyclic(0, 32).with_outputs(640)).unwrap();
    h.step_cycles(60).unwrap();
    let injected = h.inject_bit_flip(1, 2, 100) || h.inject_bit_flip(0, 2, 100);
    assert!(injected, "some slot occupied after 60 cycles");
    assert!(matches!(h.run(), Err(Error::Integrity { .. })));
}

#[test]
fn inject_into_empty_slot_reports_false() {
    let mut h = Hierarchy::new(&two_level()).unwrap();
    h.load_program(&PatternProgram::cyclic(0, 8).with_outputs(64)).unwrap();
    // Nothing stored yet.
    assert!(!h.inject_bit_flip(1, 63, 0));
    assert!(!h.inject_bit_flip(9, 0, 0), "out-of-range level");
}

#[test]
fn clean_run_after_failed_run_via_reprogram() {
    // A failed (corrupted) run must be fully recoverable by reloading the
    // program — the reset-cycle semantics of §5.4.
    let mut h = Hierarchy::new(&two_level()).unwrap();
    h.load_program(&PatternProgram::cyclic(0, 16).with_outputs(160)).unwrap();
    h.step_cycles(60).unwrap();
    h.inject_bit_flip(1, 3, 1);
    assert!(h.run().is_err());
    h.load_program(&PatternProgram::cyclic(0, 16).with_outputs(160)).unwrap();
    let r = h.run().unwrap();
    assert_eq!(r.stats.outputs, 160);
}

// ---------- configuration corners ----------

#[test]
fn five_level_hierarchy_with_osr() {
    let cfg = HierarchyConfig::builder()
        .offchip(32, 24, 1.0)
        .level(32, 512, 1, 1)
        .level(32, 256, 1, 1)
        .level(32, 128, 1, 1)
        .level(32, 64, 1, 1)
        .level(32, 32, 1, 2)
        .osr(64, vec![32])
        .build()
        .unwrap();
    let mut h = Hierarchy::new(&cfg).unwrap();
    h.load_program(&PatternProgram::cyclic(0, 16).with_outputs(320)).unwrap();
    let r = h.run().unwrap();
    assert_eq!(r.stats.outputs, 320);
    // Data traversed all five levels.
    for (i, &w) in r.stats.level_writes.iter().enumerate() {
        assert!(w >= 16, "level {i}: {w} writes");
    }
}

#[test]
fn minimum_geometry() {
    // 1 level, depth 1, cycle length 1: the degenerate but legal corner.
    let cfg = HierarchyConfig::builder()
        .offchip(32, 24, 1.0)
        .level(32, 1, 1, 2)
        .build()
        .unwrap();
    let mut h = Hierarchy::new(&cfg).unwrap();
    h.load_program(&PatternProgram::cyclic(0, 1).with_outputs(50)).unwrap();
    let r = h.run().unwrap();
    assert_eq!(r.stats.outputs, 50);
    assert_eq!(r.stats.offchip_reads, 1, "single word fetched once, reused 50x");
}

#[test]
fn strided_packed_combination() {
    // §3.2(d): stride combined with cyclic, through 128-bit packing.
    let cfg = HierarchyConfig::builder()
        .offchip(32, 24, 4.0)
        .level(128, 64, 1, 1)
        .level(128, 16, 1, 2)
        .build()
        .unwrap();
    let mut h = Hierarchy::new(&cfg).unwrap();
    h.set_collect(true);
    let mut prog = PatternProgram::cyclic(0, 16).with_outputs(160);
    prog.stride = 5;
    h.load_program(&prog).unwrap();
    let r = h.run().unwrap();
    // First packed word carries addresses 0, 5, 10, 15.
    assert_eq!(r.outputs[0].addrs, vec![0, 5, 10, 15]);
}

#[test]
fn osr_shift_selection_mid_run() {
    // §4.1.5: shifts are runtime-selectable by the µC.
    let cfg = HierarchyConfig::builder()
        .offchip(32, 24, 1.0)
        .level(32, 128, 1, 1)
        .level(32, 32, 1, 2)
        .osr(64, vec![32, 64])
        .build()
        .unwrap();
    let mut h = Hierarchy::new(&cfg).unwrap();
    h.set_collect(true);
    h.load_program(&PatternProgram::cyclic(0, 16).with_outputs(64)).unwrap();
    h.step_cycles(40).unwrap();
    h.select_osr_shift(2).unwrap(); // switch to 64-bit emissions
    let r = h.run().unwrap();
    // Mixed emission widths; unit stream still correct (run() verifies).
    assert!(r.outputs.iter().any(|o| o.addrs.len() == 1));
    assert!(r.outputs.iter().any(|o| o.addrs.len() == 2));
}

#[test]
fn disable_output_stalls_but_preloads() {
    // Table 1 `disable_output_i`: "the hierarchy will still preload data
    // from the off-chip memory".
    let mut h = Hierarchy::new(&two_level()).unwrap();
    h.load_program(&PatternProgram::cyclic(0, 32).with_outputs(320)).unwrap();
    h.set_output_enabled(false);
    h.step_cycles(200).unwrap();
    assert_eq!(h.stats().outputs, 0, "no outputs while disabled");
    assert!(h.stats().level_writes[0] >= 32, "preloading continued");
    h.set_output_enabled(true);
    let r = h.run().unwrap();
    assert_eq!(r.stats.outputs, 320);
}

#[test]
fn ib_depth_changes_timing_never_data() {
    // The data stream is invariant under the input-buffer depth; timing is
    // not, and in an interesting way: with a *single-ported* level 0 a
    // deeper prefill FIFO makes the MCU write-eager, and write-over-read
    // postpones the pattern reads — an over-aggressive prefill engine
    // starves its own read port. With a dual-ported level 0 the deeper
    // FIFO is monotonically faster (the case-study configuration).
    let prog = PatternProgram::shifted_cyclic(0, 48, 16).with_outputs(960);
    let run = |depth: u32, ports: u32| {
        let cfg = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .ib_depth(depth)
            .level(32, 256, 1, ports)
            .level(32, 64, 1, 2)
            .build()
            .unwrap();
        let mut h = Hierarchy::new(&cfg).unwrap();
        h.set_collect(true);
        h.load_program(&prog).unwrap();
        h.run().unwrap()
    };
    // Data invariance across depths and port configurations.
    let base = run(1, 1);
    for (d, p) in [(4u32, 1u32), (8, 1), (4, 2), (8, 2)] {
        let r = run(d, p);
        assert_eq!(base.outputs, r.outputs, "depth={d} ports={p} data stream");
    }
    // Dual-ported level 0: deeper FIFO never slower.
    let d1 = run(1, 2).stats.internal_cycles;
    let d8 = run(8, 2).stats.internal_cycles;
    assert!(d8 <= d1, "DP L0: deeper FIFO never slower ({d8} vs {d1})");
    // Single-ported level 0: the contention effect is real and measured.
    let sp8 = run(8, 1);
    assert!(
        sp8.stats.write_over_read_stalls[0] > 0,
        "prefill eagerness must collide with the pattern reads"
    );
}

#[test]
fn address_space_bounds_respected() {
    // A pattern that would exceed the address width panics in debug /
    // is caught by the validator at load for static overruns.
    let cfg = HierarchyConfig::builder()
        .offchip(32, 8, 1.0) // 256-word address space
        .level(32, 64, 1, 2)
        .build()
        .unwrap();
    let mut h = Hierarchy::new(&cfg).unwrap();
    // In-bounds run works.
    h.load_program(&PatternProgram::sequential(0, 200)).unwrap();
    assert!(h.run().is_ok());
}

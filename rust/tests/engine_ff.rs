//! Event-horizon fast-forward differential suite: the engine's bulk
//! skipping of quiescent cycles must be invisible in every observable —
//! stats, collected outputs, preload accounting, and mid-run
//! [`HierarchyCheckpoint`] snapshots — against the `force_naive`
//! tick-per-cycle oracle, for every §3.2 pattern family × level kind ×
//! clock ratio, warm sessions and resumed rungs included.
//!
//! The naive legs run under `debug_assertions`, which makes the engine
//! validate every *claimed* quiescence horizon against the edge it then
//! executes — so this suite also polices the per-stage
//! [`Stage::quiescent_for`](memhier::sim::engine::Stage::quiescent_for)
//! contract (a stage must never under-report its horizon) across the
//! whole matrix.

use memhier::config::HierarchyConfig;
use memhier::mem::{BudgetedRun, Hierarchy, HierarchyCheckpoint, RunResult};
use memhier::pattern::PatternProgram;
use memhier::util::{Rng, Xoshiro256};

/// The configuration matrix: the checkpoint suite's families (standard
/// narrow/wide + OSR, case-study 4x clock with deep input buffer and
/// preload, ping-pong kinds) extended with the stall-heavy shapes the
/// fast-forward targets — deep off-chip latency with a depth-1 input
/// buffer, a slow external clock, and deep latency under preload.
fn config_matrix() -> Vec<HierarchyConfig> {
    vec![
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level(32, 128, 1, 2)
            .build()
            .unwrap(),
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(128, 128, 1, 1)
            .level(128, 32, 1, 2)
            .osr(256, vec![32])
            .build()
            .unwrap(),
        HierarchyConfig::builder()
            .offchip(32, 24, 4.0)
            .ib_depth(8)
            .level(128, 104, 1, 2)
            .osr(384, vec![384])
            .preload(true)
            .build()
            .unwrap(),
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level_double_buffered(32, 128)
            .build()
            .unwrap(),
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level_double_buffered(32, 64)
            .build()
            .unwrap(),
        // Stall-heavy: 16-cycle off-chip latency through the paper's
        // depth-1 input buffer — the hierarchy is provably dead for most
        // of every fetch.
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .offchip_latency(16)
            .level(32, 64, 1, 1)
            .level(32, 16, 1, 2)
            .build()
            .unwrap(),
        // Stall-heavy ping-pong: same latency, double-buffered last level.
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .offchip_latency(16)
            .level(32, 64, 1, 1)
            .level_double_buffered(32, 16)
            .build()
            .unwrap(),
        // Slow external clock (internal 2x faster) with latency: dead
        // spans contain multiple internal edges per external edge.
        HierarchyConfig::builder()
            .offchip(32, 24, 0.5)
            .offchip_latency(8)
            .level(32, 128, 1, 1)
            .build()
            .unwrap(),
        // Deep latency under preload: exercises the derived saturation
        // window (a fixed 8-edge window would cut this preload short
        // while words are still in flight).
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .offchip_latency(16)
            .ib_depth(2)
            .level(32, 256, 1, 1)
            .preload(true)
            .build()
            .unwrap(),
    ]
}

/// One program per §3.2 pattern family, sized so every config in the
/// matrix accepts it (multiples of the widest packing factor, 4).
fn pattern_programs() -> Vec<PatternProgram> {
    vec![
        PatternProgram::sequential(0, 384),
        PatternProgram::strided(64, 4, 384),
        PatternProgram::cyclic(0, 64).with_outputs(640),
        PatternProgram::cyclic(0, 256).with_outputs(1_024),
        PatternProgram::shifted_cyclic(0, 96, 16).with_outputs(960),
        PatternProgram::shifted_cyclic(0, 64, 32).with_skip_shift(1).with_outputs(768),
    ]
}

/// Whether `prog`'s output total tiles the config's OSR emission width.
fn tiles_osr(cfg: &HierarchyConfig, prog: &PatternProgram) -> bool {
    match &cfg.osr {
        Some(o) => {
            let per_emit = (o.shifts[0] / cfg.offchip.data_width) as u64;
            prog.total_outputs % per_emit == 0
        }
        None => true,
    }
}

fn hierarchy(cfg: &HierarchyConfig, naive: bool) -> Hierarchy {
    let mut h = Hierarchy::new(cfg).expect("config valid");
    h.set_collect(true);
    h.set_force_naive(naive);
    h
}

fn run_mode(cfg: &HierarchyConfig, prog: &PatternProgram, naive: bool) -> RunResult {
    let mut h = hierarchy(cfg, naive);
    h.load_program(prog).expect("program loads");
    h.run().expect("simulation succeeds")
}

fn describe(cfg: &HierarchyConfig, prog: &PatternProgram) -> String {
    format!(
        "cfg {:?} latency {} ib {} ratio {}:{}, pattern {:?}",
        cfg.levels.iter().map(|l| (&l.kind, l.ram_depth)).collect::<Vec<_>>(),
        cfg.offchip.latency,
        cfg.offchip.ib_depth,
        cfg.offchip.external_hz,
        cfg.offchip.internal_hz,
        prog.output
    )
}

#[test]
fn fast_forward_bit_identical_to_naive_for_full_matrix() {
    for cfg in &config_matrix() {
        for prog in &pattern_programs() {
            if !tiles_osr(cfg, prog) {
                continue;
            }
            let what = describe(cfg, prog);
            let ff = run_mode(cfg, prog, false);
            let naive = run_mode(cfg, prog, true);
            assert_eq!(ff.stats, naive.stats, "{what}: stats diverged");
            assert_eq!(ff.outputs, naive.outputs, "{what}: outputs diverged");
            assert_eq!(ff.preload_cycles, naive.preload_cycles, "{what}: preload diverged");
            assert_eq!(naive.stats.skipped_cycles, 0, "{what}: naive oracle must not skip");
            assert_eq!(naive.stats.ff_jumps, 0, "{what}");
            // Preloaded resident runs legitimately skip nothing: the
            // stall-heavy fetch happens inside the preload phase, whose
            // diagnostics (like its cycle counts) are excluded from the
            // measured run.
            if cfg.offchip.latency >= 16 && !cfg.preload {
                assert!(
                    ff.stats.skipped_cycles > 0,
                    "{what}: a stall-heavy run must fast-forward"
                );
            }
        }
    }
}

/// Suspend both modes at the same seeded-random budgets; every
/// suspension's [`HierarchyCheckpoint`] must match bit for bit, and so
/// must the completed runs.
#[test]
fn checkpoints_at_random_suspend_points_match_naive() {
    let mut rng = Xoshiro256::new(0xFA57_F0D);
    for cfg in &config_matrix() {
        for prog in &pattern_programs() {
            if !tiles_osr(cfg, prog) {
                continue;
            }
            let what = describe(cfg, prog);
            let mut ff = hierarchy(cfg, false);
            let mut naive = hierarchy(cfg, true);
            ff.load_program(prog).expect("program loads");
            naive.load_program(prog).expect("program loads");
            loop {
                let delta = 1 + rng.gen_range(257);
                let a = ff.run_budgeted(delta).expect("ff leg succeeds");
                let b = naive.run_budgeted(delta).expect("naive leg succeeds");
                match (a, b) {
                    (
                        BudgetedRun::Partial { cycles: ca, units_out: ua },
                        BudgetedRun::Partial { cycles: cb, units_out: ub },
                    ) => {
                        assert_eq!((ca, ua), (cb, ub), "{what}: suspension point diverged");
                        let cka: HierarchyCheckpoint = ff.snapshot().expect("ff snapshot");
                        let ckb = naive.snapshot().expect("naive snapshot");
                        assert_eq!(cka, ckb, "{what}: checkpoint at cycle {ca} diverged");
                    }
                    (BudgetedRun::Complete(ra), BudgetedRun::Complete(rb)) => {
                        assert_eq!(ra.stats, rb.stats, "{what}: final stats diverged");
                        assert_eq!(ra.outputs, rb.outputs, "{what}: outputs diverged");
                        break;
                    }
                    (a, b) => panic!("{what}: outcomes diverged: {a:?} vs {b:?}"),
                }
            }
        }
    }
}

/// Warm sessions: back-to-back programs on one hierarchy, fast-forward vs
/// naive — and a cross-mode resume (checkpoint captured under
/// fast-forward, restored onto a naive warm session), mirroring a resumed
/// halving rung whose worker has the other setting.
#[test]
fn warm_sessions_and_cross_mode_resume_match() {
    let cfg = config_matrix()[5].clone(); // stall-heavy standard
    let progs = pattern_programs();

    let mut warm_ff = hierarchy(&cfg, false);
    let mut warm_naive = hierarchy(&cfg, true);
    for prog in &progs {
        warm_ff.load_program(prog).unwrap();
        warm_naive.load_program(prog).unwrap();
        let a = warm_ff.run().unwrap();
        let b = warm_naive.run().unwrap();
        assert_eq!(a.stats, b.stats, "warm {:?}", prog.output);
        assert_eq!(a.outputs, b.outputs, "warm {:?}", prog.output);
    }

    // Cross-mode resume: suspend under fast-forward, restore into the
    // naive session (dirtied by the loop above), finish both ways.
    let prog = &progs[2];
    warm_ff.load_program(prog).unwrap();
    assert!(matches!(warm_ff.run_budgeted(500).unwrap(), BudgetedRun::Partial { .. }));
    let ck = warm_ff.snapshot().unwrap();
    warm_naive.load_program(prog).unwrap();
    warm_naive.restore(&ck).unwrap();
    let resumed_naive = match warm_naive.run_budgeted(u64::MAX).unwrap() {
        BudgetedRun::Complete(r) => r,
        other => panic!("expected completion, got {other:?}"),
    };
    let straight = run_mode(&cfg, prog, false);
    assert_eq!(resumed_naive.stats, straight.stats, "cross-mode resume diverged");
    assert_eq!(resumed_naive.outputs, straight.outputs);
}

/// The win itself: on a deep-latency streaming run, most simulated cycles
/// are skipped, in few jumps.
#[test]
fn stall_heavy_run_skips_most_cycles() {
    let cfg = HierarchyConfig::builder()
        .offchip(32, 24, 1.0)
        .offchip_latency(64)
        .level(32, 64, 1, 1)
        .build()
        .unwrap();
    let mut h = Hierarchy::new(&cfg).unwrap();
    h.load_program(&PatternProgram::sequential(0, 256)).unwrap();
    let r = h.run().unwrap();
    let s = &r.stats;
    assert!(
        s.skipped_cycles * 2 > s.internal_cycles,
        "latency-64 stream should skip > half its cycles: {} of {}",
        s.skipped_cycles,
        s.internal_cycles
    );
    assert!(s.ff_jumps > 0);
    assert!(s.ff_jumps <= 3 * 256 + 16, "roughly one jump per fetch, got {}", s.ff_jumps);
}

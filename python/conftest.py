"""Pytest bootstrap for the python/ tree.

Makes the in-repo packages (``compile``, ``memhier_model``) importable
when pytest is invoked from the repository root or from ``python/``, and
skips the hypothesis-based property suites when ``hypothesis`` is not
installed (the offline image ships numpy/jax/pytest only).
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

collect_ignore = []
try:
    import hypothesis  # noqa: F401
except ImportError:
    collect_ignore = [
        os.path.join("tests", "test_golden_model.py"),
        os.path.join("tests", "test_kernel.py"),
    ]


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running end-to-end checks")

"""Cross-language verification: the Rust cycle-accurate simulator's output
stream vs the Python golden model (the paper's par. 5.1 methodology,
adapted: RTL -> Rust simulator, cocotb model -> this golden model).

Skipped when the Rust binary has not been built yet.
"""

import csv
import os
import subprocess
import tempfile

import pytest

from memhier_model.golden import GoldenConfig, GoldenModel, Pattern

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))


def _binary():
    for profile in ("release", "debug"):
        p = os.path.join(REPO, "target", profile, "memhier")
        if os.path.exists(p):
            return p
    return None


requires_binary = pytest.mark.skipif(
    _binary() is None, reason="memhier binary not built (cargo build)"
)


def run_simulate(cycle_length, shift, skip, outputs, stride=1):
    binary = _binary()
    with tempfile.NamedTemporaryFile(suffix=".csv", delete=False) as f:
        path = f.name
    try:
        subprocess.run(
            [
                binary, "simulate",
                "--cycle-length", str(cycle_length),
                "--shift", str(shift),
                "--skip-shift", str(skip),
                "--outputs", str(outputs),
                "--stride", str(stride),
                "--dump-outputs", path,
            ],
            check=True,
            capture_output=True,
            cwd=REPO,
        )
        with open(path) as fh:
            rows = list(csv.DictReader(fh))
        return [(int(r["addr"]), int(r["payload"], 16)) for r in rows]
    finally:
        os.unlink(path)


@requires_binary
@pytest.mark.parametrize(
    "l,s,k,n",
    [
        (64, 0, 0, 640),     # cyclic
        (64, 16, 0, 640),    # shifted cyclic
        (32, 32, 0, 320),    # sequential/linear
        (24, 6, 2, 480),     # skip-shift
    ],
)
def test_rust_stream_matches_golden_model(l, s, k, n):
    sim = run_simulate(l, s, k, n)
    golden = GoldenModel(
        GoldenConfig(level_depths=(1024, 128)),
        Pattern(cycle_length=l, inter_cycle_shift=s, skip_shift=k, total_outputs=n),
    )
    assert sim == golden.output_units()


@requires_binary
def test_rust_strided_stream_matches_golden_model():
    sim = run_simulate(16, 16, 0, 160, stride=3)
    golden = GoldenModel(
        GoldenConfig(level_depths=(1024, 128)),
        Pattern(cycle_length=16, inter_cycle_shift=16, total_outputs=160, stride=3),
    )
    assert sim == golden.output_units()


@requires_binary
def test_unique_address_counts_agree():
    sim = run_simulate(48, 12, 0, 960)
    golden = GoldenModel(
        GoldenConfig(level_depths=(1024, 128)),
        Pattern(cycle_length=48, inter_cycle_shift=12, total_outputs=960),
    )
    assert len({a for a, _ in sim}) == golden.unique_addresses()

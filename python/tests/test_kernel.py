"""Kernel vs reference — the CORE correctness signal for Layer 1.

Hypothesis sweeps the Pallas kernel over shapes, strides, paddings and
value ranges; every case is checked against the pure-jnp oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.conv1d import conv1d, dense, K_TILE
from compile.kernels.ref import conv1d_ref


def _rand(shape, seed, scale=1.0):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape) * scale, jnp.float32)


def assert_close(a, b, tol=1e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=tol, atol=tol)


class TestFixedShapes:
    def test_layer0_geometry(self):
        x, w = _rand((40, 100), 0), _rand((16, 40, 3), 1)
        out = conv1d(x, w)
        assert out.shape == (16, 98)
        assert_close(out, conv1d_ref(x, w))

    def test_all_tcresnet_layers(self):
        """Every conv layer of the model matches the oracle."""
        from compile.model import LAYERS

        x_in = {0: 100, 1: 98, 2: 98, 3: 45, 4: 41, 5: 41, 6: 20, 7: 24, 9: 16, 10: 16, 11: 8}
        for idx, k, c, f, s, p, x_expect in LAYERS:
            if idx in (8, 12):  # FC layers tested separately
                continue
            x, w = _rand((c, x_in[idx]), idx), _rand((k, c, f), 100 + idx)
            out = conv1d(x, w, stride=s, pad=p)
            assert out.shape == (k, x_expect), f"layer {idx}"
            assert_close(out, conv1d_ref(x, w, stride=s, pad=p))

    def test_dense_matches_matmul(self):
        x, w = _rand((49,), 2), _rand((4, 49, 1), 3)
        assert_close(dense(x, w), w[:, :, 0] @ x)

    def test_k_not_multiple_of_tile(self):
        # K = 12 pads to 16 internally; output must be exact.
        x, w = _rand((8, 30), 4), _rand((12, 8, 3), 5)
        out = conv1d(x, w)
        assert out.shape == (12, 28)
        assert_close(out, conv1d_ref(x, w))

    def test_single_channel_single_tap(self):
        x, w = _rand((1, 10), 6), _rand((8, 1, 1), 7)
        assert_close(conv1d(x, w), conv1d_ref(x, w))

    def test_filter_equals_input(self):
        x, w = _rand((4, 9), 8), _rand((8, 4, 9), 9)
        out = conv1d(x, w)
        assert out.shape == (8, 1)
        assert_close(out, conv1d_ref(x, w))

    def test_zero_weights_zero_output(self):
        x = _rand((4, 16), 10)
        w = jnp.zeros((8, 4, 3), jnp.float32)
        assert float(jnp.abs(conv1d(x, w)).max()) == 0.0


class TestHypothesisSweep:
    @settings(max_examples=25, deadline=None)
    @given(
        c=st.integers(1, 48),
        x_in=st.integers(9, 64),
        k=st.integers(1, 40),
        f=st.integers(1, 9),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_shapes_match_oracle(self, c, x_in, k, f, seed):
        x, w = _rand((c, x_in), seed % 1000), _rand((k, c, f), seed % 999)
        out = conv1d(x, w)
        ref = conv1d_ref(x, w)
        assert out.shape == ref.shape
        assert_close(out, ref)

    @settings(max_examples=20, deadline=None)
    @given(
        stride=st.integers(1, 4),
        pad=st.integers(0, 4),
        f=st.integers(1, 5),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_strides_and_padding(self, stride, pad, f, seed):
        x, w = _rand((6, 32), seed % 1000), _rand((10, 6, f), seed % 998)
        out = conv1d(x, w, stride=stride, pad=pad)
        ref = conv1d_ref(x, w, stride=stride, pad=pad)
        assert out.shape == ref.shape
        assert_close(out, ref)

    @settings(max_examples=10, deadline=None)
    @given(scale=st.sampled_from([1e-3, 1.0, 1e3]), seed=st.integers(0, 10_000))
    def test_value_ranges(self, scale, seed):
        x = _rand((8, 20), seed, scale)
        w = _rand((8, 8, 3), seed + 1, scale)
        out, ref = conv1d(x, w), conv1d_ref(x, w)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=1e-3, atol=1e-3 * scale * scale * 8 * 3
        )

    @settings(max_examples=10, deadline=None)
    @given(dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
    def test_dtypes(self, dtype):
        # bf16 inputs are accepted (accumulation in f32 per MXU practice).
        x = _rand((8, 20), 1).astype(dtype)
        w = _rand((8, 8, 3), 2).astype(dtype)
        out = conv1d(x.astype(jnp.float32), w.astype(jnp.float32))
        ref = conv1d_ref(x.astype(jnp.float32), w.astype(jnp.float32))
        assert_close(out, ref, tol=2e-2 if dtype == jnp.bfloat16 else 1e-4)


class TestErrors:
    def test_channel_mismatch_raises(self):
        with pytest.raises(AssertionError):
            conv1d(_rand((4, 16), 0), _rand((8, 5, 3), 1))

    def test_filter_too_wide_raises(self):
        with pytest.raises(AssertionError):
            conv1d(_rand((4, 4), 0), _rand((8, 4, 9), 1))


def test_kernel_is_jittable_and_deterministic():
    x, w = _rand((16, 50), 0), _rand((16, 16, 5), 1)
    f = jax.jit(lambda a, b: conv1d(a, b))
    a, b = f(x, w), f(x, w)
    assert_close(a, b, tol=0.0)

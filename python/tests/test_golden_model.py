"""Golden-model self-tests + hypothesis sweeps over pattern space."""

import pytest
from hypothesis import given, settings, strategies as st

from memhier_model.golden import GoldenConfig, GoldenModel, Pattern, payload_for


def mk(cfg=None, **pat):
    return GoldenModel(cfg or GoldenConfig(), Pattern(**pat))


def test_payload_matches_rust_vectors():
    # Cross-language vectors: computed by rust/src/mem/offchip.rs tests.
    a = payload_for(42, 32)
    b = payload_for(42, 32)
    assert a == b
    assert a < 2**32
    assert payload_for(42, 32) != payload_for(43, 32)
    w = payload_for(7, 128)
    assert w >> 64 != 0, "high half populated for wide words"


def test_cyclic_stream():
    m = mk(cycle_length=4, total_outputs=10)
    addrs = [a for a, _ in m.output_units()]
    assert addrs == [0, 1, 2, 3, 0, 1, 2, 3, 0, 1]
    assert m.unique_addresses() == 4


def test_shifted_cyclic_stream():
    m = mk(start_address=100, cycle_length=4, inter_cycle_shift=2, total_outputs=8)
    addrs = [a for a, _ in m.output_units()]
    assert addrs == [100, 101, 102, 103, 102, 103, 104, 105]


def test_skip_shift():
    m = mk(cycle_length=2, inter_cycle_shift=1, skip_shift=1, total_outputs=8)
    addrs = [a for a, _ in m.output_units()]
    assert addrs == [0, 1, 0, 1, 1, 2, 1, 2]


def test_strided():
    m = mk(cycle_length=4, inter_cycle_shift=4, stride=3, total_outputs=4)
    addrs = [a for a, _ in m.output_units()]
    assert addrs == [0, 3, 6, 9]


def test_packing_into_level_words():
    cfg = GoldenConfig(level_width=128)
    m = GoldenModel(cfg, Pattern(cycle_length=4, total_outputs=8))
    words = m.output_words()
    assert len(words) == 2
    addrs, bits = words[0]
    assert addrs == [0, 1, 2, 3]
    # LSB-first packing.
    assert bits & ((1 << 32) - 1) == payload_for(0, 32)
    assert (bits >> 96) & ((1 << 32) - 1) == payload_for(3, 32)


def test_osr_grouping():
    cfg = GoldenConfig(level_width=128, osr_width=384, osr_shift=384)
    m = GoldenModel(cfg, Pattern(cycle_length=12, total_outputs=24))
    words = m.output_words()
    assert len(words) == 2
    assert len(words[0][0]) == 12
    assert words[0][1] < 1 << 384


class TestValidation:
    def test_depth_bounds(self):
        with pytest.raises(ValueError):
            GoldenModel(GoldenConfig(level_depths=()), Pattern())
        with pytest.raises(ValueError):
            GoldenModel(GoldenConfig(level_depths=(1,) * 6), Pattern())

    def test_width_alignment(self):
        with pytest.raises(ValueError):
            GoldenModel(GoldenConfig(level_width=48), Pattern())

    def test_pattern_positivity(self):
        with pytest.raises(ValueError):
            mk(cycle_length=0)
        with pytest.raises(ValueError):
            mk(total_outputs=0)

    def test_shift_beyond_cycle(self):
        with pytest.raises(ValueError):
            mk(cycle_length=4, inter_cycle_shift=5)

    def test_packing_alignment(self):
        with pytest.raises(ValueError):
            GoldenModel(GoldenConfig(level_width=128), Pattern(cycle_length=6, total_outputs=12))


@settings(max_examples=60, deadline=None)
@given(
    l=st.integers(1, 64),
    s_frac=st.floats(0.0, 1.0),
    k=st.integers(0, 3),
    n=st.integers(1, 300),
    start=st.integers(0, 10_000),
)
def test_stream_invariants(l, s_frac, k, n, start):
    s = int(l * s_frac)
    m = mk(start_address=start, cycle_length=l, inter_cycle_shift=s, skip_shift=k, total_outputs=n)
    units = m.output_units()
    assert len(units) == n
    addrs = [a for a, _ in units]
    # Invariant 1: first window is start..start+min(n,l).
    head = addrs[: min(n, l)]
    assert head == list(range(start, start + len(head)))
    # Invariant 2: monotone window bases; addresses within [start, start + l + shifts*s].
    assert min(addrs) >= start
    # Invariant 3: payloads always match the address hash.
    assert all(p == payload_for(a, 32) for a, p in units)
    # Invariant 4: unique count == l + applied_shifts * s for complete cycles.
    if s > 0 and n % l == 0 and n // l >= 1:
        applied = (n // l - 1) // (k + 1)
        assert m.unique_addresses() == l + applied * min(s, l)

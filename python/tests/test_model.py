"""Layer-2 model tests: Table 2 cross-checks, shapes, determinism, and
lowering."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

# The paper's Table 2 (also hard-coded on the Rust side).
TABLE2_UNIQUE = [1920, 3456, 384, 5184, 6912, 768, 9216, 512, 196, 13824, 1536, 20736, 768]
TABLE2_CYCLE = [98, 45, 49, 41, 20, 24, 16, 24, 1, 8, 12, 4, 1]


def test_layer_table_matches_table2():
    assert len(model.LAYERS) == 13
    for (idx, k, c, f, _s, _p, x), uniq, cyc in zip(model.LAYERS, TABLE2_UNIQUE, TABLE2_CYCLE):
        assert k * c * f == uniq, f"layer {idx} weight count"
        assert (x if idx not in (8, 12) else 1) == cyc, f"layer {idx} cycle length"


def test_weight_set_fits_ultratrail_macros():
    bits = sum(k * c * f for (_, k, c, f, *_rest) in model.LAYERS) * 6
    assert bits <= 3 * 1024 * 128


def test_forward_shapes_and_determinism():
    p = model.init_params(0)
    x = jnp.asarray(np.random.RandomState(0).randn(40, 100), jnp.float32)
    l1, a1 = model.forward(p, x)
    l2, a2 = model.forward(p, x)
    assert l1.shape == (12,) and a1.shape == (4,)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


def test_forward_batch_matches_single():
    p = model.init_params(0)
    xb = jnp.asarray(np.random.RandomState(1).randn(3, 40, 100), jnp.float32)
    lb, ab = model.forward_batch(p, xb)
    assert lb.shape == (3, 12) and ab.shape == (3, 4)
    for i in range(3):
        li, ai = model.forward(p, xb[i])
        np.testing.assert_allclose(np.asarray(lb[i]), np.asarray(li), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(ab[i]), np.asarray(ai), rtol=1e-5, atol=1e-5)


def test_different_seeds_different_params():
    p0, p1 = model.init_params(0), model.init_params(1)
    assert any(
        not np.array_equal(np.asarray(a), np.asarray(b)) for a, b in zip(p0, p1)
    )


def test_input_sensitivity():
    p = model.init_params(0)
    x0 = jnp.zeros((40, 100), jnp.float32)
    x1 = jnp.ones((40, 100), jnp.float32)
    l0, _ = model.forward(p, x0)
    l1, _ = model.forward(p, x1)
    assert not np.allclose(np.asarray(l0), np.asarray(l1))


def test_param_shapes():
    for p, (idx, k, c, f, *_rest) in zip(model.init_params(0), model.LAYERS):
        assert p.shape == (k, c, f), f"layer {idx}"


def test_outputs_finite():
    p = model.init_params(0)
    x = jnp.asarray(np.random.RandomState(2).randn(40, 100) * 10, jnp.float32)
    logits, aux = model.forward(p, x)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(np.asarray(aux)).all()


@pytest.mark.slow
def test_lowering_produces_hlo_text():
    from compile.aot import lower_tcresnet, to_hlo_text

    text = to_hlo_text(lower_tcresnet(0))
    assert text.startswith("HloModule")
    assert "f32[1,40,100]" in text
    assert "f32[1,12]" in text


def test_grad_flows_through_kernel():
    """The Pallas kernel is differentiable in interpret mode — the model
    could be trained end to end (paper's accelerator is inference-only,
    but the build path supports fwd/bwd)."""
    p = model.init_params(0)
    x = jnp.asarray(np.random.RandomState(3).randn(40, 100), jnp.float32)

    def loss(params):
        logits, _ = model.forward(params, x)
        return jnp.sum(logits**2)

    grads = jax.grad(loss)(p)
    assert any(float(jnp.abs(g).max()) > 0 for g in grads)

"""Pure-jnp oracle for the Pallas conv1d kernel.

Uses lax.conv_general_dilated (XLA's native convolution) — an independent
implementation path against which the MAC-array kernel is verified
bit-tolerantly (the kernel accumulates per-tap in f32, the oracle via the
conv primitive, so equality is to float tolerance).
"""

import jax.numpy as jnp
from jax import lax


def conv1d_ref(x, w, *, stride: int = 1, pad: int = 0):
    """Reference temporal convolution.

    x: (C, X_in); w: (K, C, F) -> (K, X_out)
    """
    # lax conv wants NCW / OIW.
    out = lax.conv_general_dilated(
        x[None, :, :].astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride,),
        padding=[(pad, pad)],
        dimension_numbers=("NCH", "OIH", "NCH"),
    )
    return out[0]


def dense_ref(x, w):
    """Reference FC: (K, C) @ (C,)."""
    return w[:, :, 0] @ x if w.ndim == 3 else w @ x

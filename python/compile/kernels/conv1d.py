"""Layer 1 — the Pallas temporal-convolution kernel.

UltraTrail's compute hot-spot is an 8x8 MAC array performing an
output-stationary dot product per cycle: 8 output channels x 8 input
channels, weights held at the 384-bit port while the time loop streams.

Hardware adaptation (GPU/ASIC -> TPU thinking, see DESIGN.md
par. Hardware-Adaptation): the MAC array maps onto the MXU systolic array
as a (K_tile x C) x (C x X) matmul per filter tap; the memory hierarchy's
role — staging the per-tap weight port words close to the compute — maps
onto VMEM via the weight BlockSpec (one K-tile of weights resident per
grid step, exactly the shifted-cyclic reuse the paper's MCU provides).
The filter-tap loop is unrolled (F is static and small, <= 9), so the
weight tile is reused X times per tap — Table 2's "cycle length".

The kernel MUST run with interpret=True on CPU: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# K-tile: the MAC array's output-channel unroll (8 rows).
K_TILE = 8


def _conv1d_kernel(x_ref, w_ref, o_ref, *, f_taps: int, x_out: int):
    """One grid step: compute a K_TILE x x_out output tile.

    x_ref: (C, x_in)   — full input (VMEM-resident; HBM->VMEM staging is
                          what the paper's hierarchy does off-chip->L0).
    w_ref: (K_TILE, C, F) — this K-tile's weights (the "port words").
    o_ref: (K_TILE, x_out)
    """
    acc = jnp.zeros((K_TILE, x_out), dtype=jnp.float32)
    # Unrolled filter-tap loop: per tap, one MXU matmul
    # (K_TILE, C) @ (C, x_out). The weight matrix stays resident (weight-
    # stationary), the input window slides by one — the shifted-cyclic
    # access pattern of par. 3.2(c).
    for f in range(f_taps):
        w_f = w_ref[:, :, f]
        x_f = x_ref[:, f : f + x_out]
        acc = acc + jnp.dot(w_f, x_f, preferred_element_type=jnp.float32)
    o_ref[:, :] = acc


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _conv1d_core(x, w, stride: int, pad: int):
    return _conv1d_fwd_impl(x, w, stride, pad)


def _conv1d_vjp_fwd(x, w, stride, pad):
    return _conv1d_fwd_impl(x, w, stride, pad), (x, w)


def _conv1d_vjp_bwd(stride, pad, res, g):
    # Backward through the mathematically-identical XLA convolution: the
    # Pallas forward has no registered transpose in interpret mode, and
    # training runs at build time only, so precision parity is all that
    # matters.
    from .ref import conv1d_ref

    x, w = res
    _, vjp = jax.vjp(lambda xx, ww: conv1d_ref(xx, ww, stride=stride, pad=pad), x, w)
    return vjp(g)


_conv1d_core.defvjp(_conv1d_vjp_fwd, _conv1d_vjp_bwd)


def conv1d(x, w, *, stride: int = 1, pad: int = 0):
    """Temporal convolution via the Pallas MAC-array kernel.

    x: (C, X_in) float32
    w: (K, C, F) float32, K a multiple of K_TILE (padded otherwise)
    returns: (K, X_out) with X_out = (X_in + 2*pad - F) // stride + 1

    Differentiable: the forward pass is the Pallas kernel, the backward
    pass routes through the XLA conv primitive (custom VJP).
    """
    return _conv1d_core(x, w, stride, pad)


@functools.partial(jax.jit, static_argnames=("stride", "pad"))
def _conv1d_fwd_impl(x, w, stride: int = 1, pad: int = 0):
    c, x_in = x.shape
    k, wc, f = w.shape
    assert wc == c, f"channel mismatch: x has {c}, w has {wc}"
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad)))
        x_in = x_in + 2 * pad
    x_out_full = x_in - f + 1
    assert x_out_full >= 1, "filter wider than (padded) input"

    # Pad K up to a multiple of the MAC-array tile (partial tiles waste
    # array rows, the utilization effect of par. 5.3).
    k_pad = (-k) % K_TILE
    if k_pad:
        w = jnp.pad(w, ((0, k_pad), (0, 0), (0, 0)))
    k_tiles = (k + k_pad) // K_TILE

    out = pl.pallas_call(
        functools.partial(_conv1d_kernel, f_taps=f, x_out=x_out_full),
        grid=(k_tiles,),
        in_specs=[
            # Full input resident per step (L0 of the hierarchy).
            pl.BlockSpec((c, x_in), lambda i: (0, 0)),
            # One K-tile of weights per step (the OSR port words).
            pl.BlockSpec((K_TILE, c, f), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((K_TILE, x_out_full), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((k_tiles * K_TILE, x_out_full), jnp.float32),
        interpret=True,  # CPU path; real-TPU perf estimated in DESIGN.md
    )(x, w)

    out = out[:k]
    if stride > 1:
        out = out[:, ::stride]
    return out


def dense(x, w):
    """FC layer on the same array: a single (K, C) @ (C,) product.

    x: (C,), w: (K, C, 1) — an F=1 convolution over a length-1 signal.
    """
    assert w.ndim == 3 and w.shape[2] == 1
    return conv1d(x[:, None], w)[:, 0]

"""Layer 2 — the TC-ResNet keyword-spotting model in JAX.

The 13-layer network of the UltraTrail case study (Table 2 of the paper):
a 3-tap stem over 40 MFCC channels, three residual blocks, a squeeze
branch, an auxiliary FC head and the 12-class classifier. Every conv layer
calls the Pallas MAC-array kernel (kernels.conv1d), so the whole forward
pass lowers into a single HLO module.

Layer geometry (channels, taps, strides, paddings) is chosen so that each
layer's weight count and output width reproduce Table 2 exactly — the same
table the Rust model (`rust/src/model/tcresnet.rs`) hard-codes; the two
are cross-checked by tests on both sides.
"""

import jax
import jax.numpy as jnp

from .kernels.conv1d import conv1d, dense

# (idx, K, C, F, stride, pad, expected_X_out) — Table 2 cross-check.
LAYERS = [
    (0, 16, 40, 3, 1, 0, 98),   # stem           (input X = 100)
    (1, 24, 16, 9, 2, 0, 45),   # block1 conv1
    (2, 24, 16, 1, 2, 0, 49),   # block1 shortcut
    (3, 24, 24, 9, 1, 2, 41),   # block1 conv2
    (4, 32, 24, 9, 2, 3, 20),   # block2 conv1
    (5, 32, 24, 1, 2, 3, 24),   # block2 shortcut
    (6, 32, 32, 9, 1, 2, 16),   # block2 conv2
    (7, 32, 16, 1, 1, 0, 24),   # squeeze branch
    (8, 4, 49, 1, 1, 0, 1),     # aux FC head
    (9, 48, 32, 9, 2, 4, 8),    # block3 conv1
    (10, 48, 32, 1, 2, 4, 12),  # block3 shortcut
    (11, 48, 48, 9, 1, 2, 4),   # block3 conv2
    (12, 12, 64, 1, 1, 0, 1),   # classifier (12 keyword classes)
]

MFCC_BINS = 40
MFCC_FRAMES = 100  # stem reduces to 98 = Table 2 layer-0 cycle length
N_CLASSES = 12


def init_params(seed: int = 0):
    """Deterministic parameter set: one weight tensor per layer."""
    key = jax.random.PRNGKey(seed)
    params = []
    for idx, k, c, f, *_ in LAYERS:
        key, sub = jax.random.split(key)
        scale = 1.0 / jnp.sqrt(c * f)
        params.append(jax.random.normal(sub, (k, c, f), dtype=jnp.float32) * scale)
    return params


def _relu(x):
    return jnp.maximum(x, 0.0)


def forward(params, x):
    """TC-ResNet forward pass.

    x: (MFCC_BINS, MFCC_FRAMES) float32 -> (logits (N_CLASSES,), aux (4,))
    """
    w = {idx: p for (idx, *_), p in zip(LAYERS, params)}
    spec = {l[0]: l for l in LAYERS}

    def cv(i, t):
        _, _, _, _, s, p, _ = spec[i]
        return conv1d(t, w[i], stride=s, pad=p)

    y0 = _relu(cv(0, x))                         # (16, 98)

    # Block 1.
    m1 = _relu(cv(1, y0))                        # (24, 45)
    m1 = cv(3, m1)                               # (24, 41)
    s1 = cv(2, y0)                               # (24, 49)
    y1 = _relu(m1 + s1[:, :41])                  # (24, 41)

    # Auxiliary head on the block-1 shortcut (channel-mean -> FC 49 -> 4).
    aux_feat = jnp.mean(s1, axis=0)              # (49,)
    aux = dense(aux_feat, w[8])                  # (4,)

    # Block 2 with the squeeze branch (layer 7 on 16 stem channels).
    m2 = _relu(cv(4, y1))                        # (32, 20)
    m2 = cv(6, m2)                               # (32, 16)
    s2 = cv(5, y1)                               # (32, 24)
    sq = cv(7, y0[:16, :24])                     # (32, 24)
    y2 = _relu(m2 + s2[:, :16] + sq[:, :16])     # (32, 16)

    # Block 3.
    m3 = _relu(cv(9, y2))                        # (48, 8)
    m3 = cv(11, m3)                              # (48, 4)
    s3 = cv(10, y2)                              # (48, 12)
    y3 = _relu(m3 + s3[:, :4])                   # (48, 4)

    # Classifier features: time-mean (48) + first 16 time-max channels.
    feat = jnp.concatenate([jnp.mean(y3, axis=1), jnp.max(y3, axis=1)[:16]])  # (64,)
    logits = dense(feat, w[12])                  # (12,)
    return logits, aux


def forward_batch(params, xb):
    """Batched forward: xb (B, MFCC_BINS, MFCC_FRAMES)."""
    return jax.vmap(lambda x: forward(params, x))(xb)

"""AOT lowering: JAX/Pallas -> HLO text artifacts for the Rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example.

Artifacts (all under artifacts/):
  tcresnet.hlo.txt — the full TC-ResNet forward pass (batch 1), weights
                     baked in as constants (the accelerator's weight set).
  conv1d.hlo.txt   — the standalone Pallas conv kernel (layer-0 shape),
                     used by the Rust kernel-level integration test.
  meta.json        — shapes + provenance for the Rust loader.

Python runs ONLY here (`make artifacts`); never on the request path.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.conv1d import conv1d


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_tcresnet(seed: int):
    params = model.init_params(seed)

    def infer(x):
        logits, aux = model.forward_batch(params, x)
        return (logits, aux)

    spec = jax.ShapeDtypeStruct((1, model.MFCC_BINS, model.MFCC_FRAMES), jnp.float32)
    return jax.jit(infer).lower(spec)


def lower_conv_kernel():
    # Layer-0 geometry: (40, 100) x (16, 40, 3) -> (16, 98).
    def f(x, w):
        return (conv1d(x, w),)

    xs = jax.ShapeDtypeStruct((40, 100), jnp.float32)
    ws = jax.ShapeDtypeStruct((16, 40, 3), jnp.float32)
    return jax.jit(f).lower(xs, ws)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    text = to_hlo_text(lower_tcresnet(args.seed))
    path = os.path.join(args.out_dir, "tcresnet.hlo.txt")
    with open(path, "w") as fh:
        fh.write(text)
    print(f"wrote {path} ({len(text)} chars)")

    text = to_hlo_text(lower_conv_kernel())
    path = os.path.join(args.out_dir, "conv1d.hlo.txt")
    with open(path, "w") as fh:
        fh.write(text)
    print(f"wrote {path} ({len(text)} chars)")

    meta = {
        "model": "tc-resnet8 (Table 2 geometry)",
        "input": [1, model.MFCC_BINS, model.MFCC_FRAMES],
        "outputs": {"logits": [1, model.N_CLASSES], "aux": [1, 4]},
        "kernel_input": {"x": [40, 100], "w": [16, 40, 3]},
        "seed": args.seed,
        "jax": jax.__version__,
    }
    path = os.path.join(args.out_dir, "meta.json")
    with open(path, "w") as fh:
        json.dump(meta, fh, indent=2)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()

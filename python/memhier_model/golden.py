"""Python golden model of the memory framework (paper par. 5.1).

The paper verified its SystemVerilog design against a Python model built
on the bitstring package; this is the equivalent for our Rust simulator,
using arbitrary-precision Python ints for the bit-level data path
(LSB-first packing, like the RTL register file).

The golden model is *untimed*: it computes the exact expected output
stream — addresses and payload bits — for a configuration + pattern
program. The Rust simulator exports its output stream (CSV via
`Hierarchy::set_collect`) and integration tests compare the two. A
cycle-count *bound* check complements it (see rust/src/mem/functional.rs
for the timed oracle on the Rust side).
"""

from dataclasses import dataclass, field


def payload_for(addr: int, width: int) -> int:
    """SplitMix64 finalizer — must match rust/src/mem/offchip.rs."""
    mask64 = (1 << 64) - 1
    z = (addr + 0x9E3779B97F4A7C15) & mask64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & mask64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & mask64
    z = z ^ (z >> 31)
    if width <= 64:
        return z & ((1 << width) - 1)
    hi = (z * 0xD6E8FEB86659FD93) & mask64
    return ((hi << 64) | z) & ((1 << width) - 1)


@dataclass
class GoldenConfig:
    """Mirror of the Rust HierarchyConfig fields the model needs."""

    offchip_width: int = 32
    level_width: int = 32
    level_depths: tuple = (1024, 128)
    osr_width: int = 0          # 0 = no OSR
    osr_shift: int = 0

    def validate(self):
        if not 1 <= len(self.level_depths) <= 5:
            raise ValueError("hierarchy depth must be 1..5")
        if self.level_width % self.offchip_width:
            raise ValueError("level width must be a multiple of the off-chip width")
        if self.osr_width:
            if self.osr_width < self.level_width:
                raise ValueError("OSR narrower than last level")
            if self.osr_shift % self.offchip_width:
                raise ValueError("OSR shift must align to off-chip words")


@dataclass
class Pattern:
    """Table 1 pattern registers (output program)."""

    start_address: int = 0
    cycle_length: int = 8
    inter_cycle_shift: int = 0
    skip_shift: int = 0
    stride: int = 1
    total_outputs: int = 64

    def validate(self, cfg: GoldenConfig):
        if self.cycle_length <= 0 or self.stride <= 0 or self.total_outputs <= 0:
            raise ValueError("pattern parameters must be positive")
        if self.inter_cycle_shift > self.cycle_length:
            raise ValueError("inter-cycle shift beyond cycle length is undefined")
        pack = cfg.level_width // cfg.offchip_width
        for name, v in [("cycle_length", self.cycle_length), ("total_outputs", self.total_outputs)]:
            if v % pack:
                raise ValueError(f"{name} must be a multiple of the packing factor {pack}")


@dataclass
class GoldenModel:
    """Untimed reference of the framework's output behaviour."""

    cfg: GoldenConfig
    pattern: Pattern
    _units: list = field(default_factory=list)

    def __post_init__(self):
        self.cfg.validate()
        self.pattern.validate(self.cfg)

    def output_units(self):
        """Expected (address, payload) per off-chip word unit, in order."""
        if self._units:
            return self._units
        p, out = self.pattern, []
        ptr = offset = skips = 0
        while len(out) < p.total_outputs:
            unit = offset + ptr
            addr = p.start_address + unit * p.stride
            out.append((addr, payload_for(addr, self.cfg.offchip_width)))
            ptr += 1
            if ptr == p.cycle_length:
                ptr = 0
                skips += 1
                if skips > p.skip_shift:
                    skips = 0
                    offset += p.inter_cycle_shift
        self._units = out
        return out

    def output_words(self):
        """Expected accelerator-facing words: packed level words, or OSR
        emissions if an OSR is configured. Returns (addr_list, int_bits)."""
        units = self.output_units()
        group = (
            self.cfg.osr_shift // self.cfg.offchip_width
            if self.cfg.osr_width
            else self.cfg.level_width // self.cfg.offchip_width
        )
        words = []
        for i in range(0, len(units), group):
            chunk = units[i : i + group]
            bits = 0
            for j, (_, payload) in enumerate(chunk):
                bits |= payload << (j * self.cfg.offchip_width)
            words.append(([a for a, _ in chunk], bits))
        return words

    def unique_addresses(self):
        """Off-chip words fetched (each unique address once for resident
        patterns)."""
        return len({a for a, _ in self.output_units()})

//! Quickstart: build a two-level hierarchy, run a shifted-cyclic pattern,
//! and read off performance, area and power — the 30-second tour of the
//! public API.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use memhier::config::HierarchyConfig;
use memhier::cost::{hierarchy_area, run_power};
use memhier::mem::Hierarchy;
use memhier::pattern::PatternProgram;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. Configure the framework (§4.1 parameters): 32-bit off-chip
    //    interface, a 1024-word single-ported level 0 and a 128-word
    //    dual-ported level 1.
    let cfg = HierarchyConfig::builder()
        .offchip(32, 24, 1.0)
        .level(32, 1024, 1, 1)
        .level(32, 128, 1, 2)
        .build()?;

    // 2. Program a pattern (Table 1 registers): shifted-cyclic windows of
    //    96 words advancing by 16 per cycle, 5,000 outputs.
    let prog = PatternProgram::shifted_cyclic(0, 96, 16).with_outputs(5_000);

    // 3. Simulate cycle-accurately. Data integrity is verified end to end
    //    (payloads are an address hash checked at the output port).
    let mut h = Hierarchy::new(&cfg)?;
    h.load_program(&prog)?;
    let run = h.run()?;

    println!("cycles       : {}", run.stats.internal_cycles);
    println!("outputs      : {}", run.stats.outputs);
    println!("efficiency   : {:.1}% of one word/cycle", run.stats.efficiency() * 100.0);
    println!(
        "off-chip     : {} reads ({:.2} per output — data reuse!)",
        run.stats.offchip_reads,
        run.stats.offchip_reads_per_output()
    );

    // 4. Cost the configuration with the synthesis-proxy models.
    let area = hierarchy_area(&cfg);
    let power = run_power(&cfg, &run.stats, 100e6);
    println!(
        "chip area    : {:.0} um^2 (levels {:.0}+{:.0}, control {:.0})",
        area.total, area.levels[0], area.levels[1], area.control
    );
    println!("power @100MHz: {:.3} mW", power.total * 1e3);

    // 5. Compare against preloading (§5.2.1): fills happen in idle time.
    let cfg_pre = HierarchyConfig::builder()
        .offchip(32, 24, 1.0)
        .level(32, 1024, 1, 1)
        .level(32, 128, 1, 2)
        .preload(true)
        .build()?;
    let mut h = Hierarchy::new(&cfg_pre)?;
    h.load_program(&prog)?;
    let pre = h.run()?;
    println!(
        "preloading   : {} -> {} cycles ({:.1}% faster)",
        run.stats.internal_cycles,
        pre.stats.internal_cycles,
        (1.0 - pre.stats.internal_cycles as f64 / run.stats.internal_cycles as f64) * 100.0
    );
    Ok(())
}

//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! Loads the AOT-compiled TC-ResNet (JAX model + Pallas MAC-array kernel,
//! lowered to HLO at build time — run `make artifacts` first), serves a
//! batch of synthetic keyword-spotting requests through the PJRT runtime,
//! and co-simulates the weight stream through the paper's memory
//! hierarchy (104×128-bit dual-ported level + 384-bit OSR) to report the
//! accelerator-side latency. Finishes with the case-study summary
//! (area −62 %, power +6 %, perf −2 %).
//!
//! ```sh
//! make artifacts && cargo run --release --example kws_e2e
//! ```

use memhier::accel::UltraTrail;
use memhier::coordinator::{synth_request, KwsServer, ServerConfig};
use memhier::report;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let artifact = std::path::Path::new("artifacts/tcresnet.hlo.txt");

    println!("== serving phase ==");
    let mut server = KwsServer::new(
        artifact,
        ServerConfig { max_batch: 8, ..ServerConfig::default() },
    )?;
    let requests: Vec<_> = (0..64u64).map(synth_request).collect();
    let t0 = std::time::Instant::now();
    let results = server.serve_stream(requests)?;
    let wall = t0.elapsed();
    let stats = server.stats().clone();
    println!(
        "served {} requests in {:?} — {:.1} req/s host-side, {} batches",
        results.len(),
        wall,
        results.len() as f64 / wall.as_secs_f64(),
        stats.batches
    );
    let accel = results[0].accel_cycles.expect("co-simulation enabled");
    println!(
        "accelerator model: {} cycles/inference = {:.1} ms @250 kHz (budget: 100 ms)",
        accel,
        accel as f64 / 250e3 * 1e3
    );
    let mut hist = vec![0usize; memhier::coordinator::N_CLASSES];
    for r in &results {
        hist[r.class] += 1;
    }
    println!("predicted-class histogram: {hist:?}");
    assert_eq!(results.len(), 64, "all requests served");
    assert!(
        results.iter().all(|r| r.logits.len() == memhier::coordinator::N_CLASSES),
        "logit shape"
    );

    println!("\n== case-study summary (Fig 12 + headline) ==");
    println!("{}", report::fig12_table(true)?.render());

    let cs = UltraTrail::default().case_study(true)?;
    println!(
        "headline: chip area {:+.1}%, power {:+.1}%, performance {:+.1}% (paper: -62.2%, +6.2%, +2.4%)",
        cs.area_delta * 100.0,
        cs.power_delta * 100.0,
        cs.perf_loss * 100.0
    );
    Ok(())
}

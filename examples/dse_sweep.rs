//! Design-space exploration: the workflow the paper motivates in §1 —
//! semi-automatically generate and evaluate hierarchy configurations for
//! a target workload, then pick from the area/power/runtime Pareto front.
//!
//! ```sh
//! cargo run --release --example dse_sweep
//! # Additionally write every evaluated point as CSV (CI publishes this
//! # as a trend-tracking artifact):
//! cargo run --release --example dse_sweep -- --csv dse_sweep.csv
//! # Joint mapping × hierarchy co-exploration (adds mapping columns
//! # uk,uc,ux,uf,order and the offchip_reads axis to the CSV):
//! cargo run --release --example dse_sweep -- --joint --csv dse_joint_sweep.csv
//! ```

use memhier::dse::{
    explore, explore_halving_pruned, explore_joint, explore_joint_halving_pruned, ff_totals,
    DesignPoint, HalvingSchedule, HalvingStats, JointSpace, KindChoice, SearchSpace,
};
use memhier::loopnest::LoopOrder;
use memhier::model::{LayerKind, LayerSpec};
use memhier::pattern::PatternProgram;
use memhier::util::table::{fnum, TextTable};

/// Compact one-token description of a configuration's level stack.
fn stack_desc(p: &DesignPoint) -> String {
    p.config.stack_desc()
}

/// Render the successive-halving work accounting as a one-row CSV (the
/// CI artifact that tracks how much sweep work checkpoint-resume and the
/// analytical bound-and-prune prescreen save).
fn halving_csv(stats: &HalvingStats) -> String {
    format!(
        "candidates,screen_exact,pruned,full_runs,skipped,resumed_cycles,saved_cycles,\
         bound_pruned,bound_cycles_saved\n\
         {},{},{},{},{},{},{},{},{}\n",
        stats.candidates,
        stats.screen_exact,
        stats.pruned,
        stats.full_runs,
        stats.skipped,
        stats.resumed_cycles,
        stats.saved_cycles,
        stats.bound_pruned,
        stats.bound_cycles_saved
    )
}

/// Joint-sweep CSV: the config columns plus the mapping that produced
/// each row (`uk,uc,ux,uf,order`) and the fourth Pareto axis,
/// `offchip_reads`. Only written under `--joint`, so the default
/// artifact stays byte-identical.
fn to_joint_csv(points: &[DesignPoint]) -> String {
    let mut csv = String::from(
        "config,levels,word_width,osr_width,uk,uc,ux,uf,order,area_um2,power_w,cycles,efficiency,offchip_reads,on_front\n",
    );
    for p in points {
        let m = p.mapping.expect("joint points carry their mapping");
        csv.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{:.1},{:.9},{},{:.6},{},{}\n",
            stack_desc(p),
            p.config.levels.len(),
            p.config.levels[0].word_width,
            p.config.osr.as_ref().map(|o| o.width).unwrap_or(0),
            m.unrolling.uk,
            m.unrolling.uc,
            m.unrolling.ux,
            m.unrolling.uf,
            m.order_name(),
            p.area,
            p.power,
            p.cycles,
            p.efficiency,
            p.offchip_reads,
            p.on_front
        ));
    }
    csv
}

/// The `--joint` sweep: prepend the mapping dimension (spatial unrolling
/// × temporal loop order over one conv layer) to the hierarchy space and
/// explore *(mapping, config)* pairs on the four-axis Pareto front
/// (area, power, cycles, off-chip reads).
fn joint_sweep(csv_path: Option<String>) -> Result<(), Box<dyn std::error::Error>> {
    let layer = LayerSpec { idx: 0, kind: LayerKind::Conv, k: 16, c: 8, f: 3, x: 4 };
    let space = SearchSpace {
        depths: vec![1, 2],
        ram_depths: vec![32, 64, 128, 256, 512],
        word_widths: vec![32, 128],
        level_kinds: vec![KindChoice::Standard, KindChoice::DoubleBuffered],
        try_dual_ported: true,
        eval_hz: 100e6,
    };
    let joint = JointSpace::new(
        space,
        layer,
        16,
        &[LoopOrder::ultratrail(), LoopOrder::output_stationary()],
    );
    println!(
        "joint workload: conv layer K={} C={} F={} X={}, {} supported mappings on a 16-MAC array\n",
        layer.k,
        layer.c,
        layer.f,
        layer.x,
        joint.mappings.len()
    );

    let explored = explore_joint(&joint)?;
    let mut t = TextTable::new(vec![
        "config", "uk", "uc", "ux", "uf", "order", "area_um2", "power_mW", "cycles", "offchip",
        "eff", "",
    ]);
    for p in explored.points.iter().filter(|p| p.on_front) {
        let m = p.mapping.expect("joint points carry their mapping");
        t.row(vec![
            stack_desc(p),
            m.unrolling.uk.to_string(),
            m.unrolling.uc.to_string(),
            m.unrolling.ux.to_string(),
            m.unrolling.uf.to_string(),
            m.order_name().to_string(),
            fnum(p.area, 0),
            fnum(p.power * 1e3, 3),
            p.cycles.to_string(),
            p.offchip_reads.to_string(),
            fnum(p.efficiency, 3),
            "pareto".to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "{} of {} evaluated (mapping, config) points are on the 4-axis Pareto front \
         (area, power, cycles, off-chip reads)",
        explored.points.iter().filter(|p| p.on_front).count(),
        explored.points.len()
    );
    let js = &explored.stats;
    println!(
        "joint pruning: {} enumerated, {} bound-pruned, {} simulated, {} memo hits, {} skipped, \
         >= {} simulated cycles avoided",
        js.enumerated, js.bound_pruned, js.simulated, js.memo_hits, js.skipped, js.cycles_saved_lb
    );

    // The same joint sweep through the bound-and-pruned successive-halving
    // rungs — front must match the exhaustive one bit for bit.
    let schedule = HalvingSchedule::for_workloads(&joint.workloads);
    let halved = explore_joint_halving_pruned(&joint, &schedule)?;
    let st = &halved.stats;
    println!(
        "\nhalving sweep: {} candidates -> {} exact-from-screen, {} pruned, {} resumed \
         completions, {} skipped, {} bound-pruned",
        st.candidates, st.screen_exact, st.pruned, st.full_runs, st.skipped, st.bound_pruned
    );
    let front = |pts: &[DesignPoint]| pts.iter().filter(|p| p.on_front).count();
    println!(
        "halving front {} points vs exhaustive front {} points",
        front(&halved.points),
        front(&explored.points)
    );

    if let Some(path) = csv_path {
        std::fs::write(&path, to_joint_csv(&explored.points))?;
        println!("\nwrote {} rows to {path}", explored.points.len());
        let hpath = format!("{}.halving.csv", path.trim_end_matches(".csv"));
        std::fs::write(&hpath, halving_csv(st))?;
        println!("wrote halving work accounting to {hpath}");
    }
    Ok(())
}

/// Render every evaluated point as CSV (one row per configuration).
fn to_csv(points: &[DesignPoint]) -> String {
    let mut csv = String::from("config,levels,word_width,osr_width,area_um2,power_w,cycles,efficiency,on_front\n");
    for p in points {
        csv.push_str(&format!(
            "{},{},{},{},{:.1},{:.9},{},{:.6},{}\n",
            stack_desc(p),
            p.config.levels.len(),
            p.config.levels[0].word_width,
            p.config.osr.as_ref().map(|o| o.width).unwrap_or(0),
            p.area,
            p.power,
            p.cycles,
            p.efficiency,
            p.on_front
        ));
    }
    csv
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let csv_path = args
        .iter()
        .position(|a| a == "--csv")
        .and_then(|i| args.get(i + 1))
        .cloned();
    // `--joint` switches on the mapping dimension (default off, so the
    // config-only sweep's output stays byte-identical).
    if args.iter().any(|a| a == "--joint") {
        return joint_sweep(csv_path);
    }
    // Workload: the kind of overlapping window a conv layer's input data
    // set produces — cycle length 128, shift 32.
    let workload = PatternProgram::shifted_cyclic(0, 128, 32).with_outputs(5_120);
    println!(
        "workload: shifted-cyclic l=128 s=32, {} outputs, {} unique words\n",
        workload.total_outputs,
        workload.unique_addresses()
    );

    let space = SearchSpace {
        depths: vec![1, 2, 3],
        ram_depths: vec![32, 64, 128, 256, 512],
        word_widths: vec![32, 128],
        // Both level kinds: the sweep decides per level whether the §6
        // ping-pong scheme earns its mux (kind letter P in the CSV).
        level_kinds: vec![KindChoice::Standard, KindChoice::DoubleBuffered],
        try_dual_ported: true,
        eval_hz: 100e6,
    };
    let points = explore(&space, &workload)?;

    let mut t = TextTable::new(vec!["config", "area_um2", "power_mW", "cycles", "eff", ""]);
    for p in points.iter().filter(|p| p.on_front) {
        t.row(vec![
            stack_desc(p),
            fnum(p.area, 0),
            fnum(p.power * 1e3, 3),
            p.cycles.to_string(),
            fnum(p.efficiency, 3),
            "pareto".to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "{} of {} evaluated configurations are Pareto-optimal",
        points.iter().filter(|p| p.on_front).count(),
        points.len()
    );
    let (skipped, simulated, jumps) = ff_totals(&points);
    println!(
        "engine fast-forward: {skipped} of {simulated} simulated cycles skipped in {jumps} \
         jumps ({:.1}%)",
        100.0 * skipped as f64 / simulated.max(1) as f64
    );

    // The trade the paper highlights: the cheapest full-throughput config
    // vs the absolute cheapest.
    let full = points.iter().filter(|p| p.efficiency > 0.95).min_by(|a, b| a.area.total_cmp(&b.area));
    let cheapest = points.first();
    if let (Some(f), Some(c)) = (full, cheapest) {
        println!(
            "\ncheapest full-throughput: {:.0} um^2 @ {} cycles; absolute cheapest: {:.0} um^2 @ {} cycles",
            f.area, f.cycles, c.area, c.cycles
        );
    }

    // The same sweep as a bound-and-pruned, checkpoint-resumed
    // successive-halving run: the analytical prescreen drops
    // provably-dominated candidates before rung 0, screened prefixes are
    // inherited across rungs instead of re-paid, and the front must still
    // match the exhaustive one bit for bit.
    let schedule = HalvingSchedule::for_workload(&workload);
    let halved = explore_halving_pruned(&space, &workload, &schedule)?;
    let st = &halved.stats;
    println!(
        "\nhalving sweep: {} candidates -> {} exact-from-screen, {} pruned, {} resumed \
         completions, {} skipped",
        st.candidates, st.screen_exact, st.pruned, st.full_runs, st.skipped
    );
    println!(
        "bound-and-prune: {} candidates bound-pruned before rung 0, >= {} simulated cycles \
         avoided",
        st.bound_pruned, st.bound_cycles_saved
    );
    println!(
        "resume accounting: {} cycles inherited from checkpoints (saved), {} cycles simulated \
         as resume deltas",
        st.saved_cycles, st.resumed_cycles
    );
    let (hskipped, hsim, hjumps) = ff_totals(&halved.points);
    println!(
        "engine fast-forward (halving): {hskipped} of {hsim} cycles skipped in {hjumps} jumps"
    );
    let front = |pts: &[DesignPoint]| pts.iter().filter(|p| p.on_front).count();
    println!(
        "halving front {} points vs exhaustive front {} points",
        front(&halved.points),
        front(&points)
    );

    if let Some(path) = csv_path {
        std::fs::write(&path, to_csv(&points))?;
        println!("\nwrote {} rows to {path}", points.len());
        let hpath = format!("{}.halving.csv", path.trim_end_matches(".csv"));
        std::fs::write(&hpath, halving_csv(st))?;
        println!("wrote halving work accounting to {hpath}");
    }
    Ok(())
}

use memhier::config::HierarchyConfig;
use memhier::mem::Hierarchy;
use memhier::pattern::PatternProgram;
fn main() {
    let cfg = HierarchyConfig::builder().offchip(32, 24, 1.0).level(32, 1024, 1, 1).level(32, 128, 1, 2).build().unwrap();
    for _ in 0..40 {
        let mut h = Hierarchy::new(&cfg).unwrap();
        h.load_program(&PatternProgram::cyclic(0, 64).with_outputs(50_000)).unwrap();
        h.set_verify(false);
        std::hint::black_box(h.run().unwrap().stats.internal_cycles);
    }
}

//! Pattern explorer: generate every §3.2 access-pattern family, classify
//! raw traces back to parameters, and visualize hierarchy behaviour with
//! a Fig-4-style waveform.
//!
//! ```sh
//! cargo run --release --example pattern_explorer
//! ```

use memhier::config::HierarchyConfig;
use memhier::mem::Hierarchy;
use memhier::pattern::{classify_trace, AccessPattern, PatternProgram};
use memhier::pattern::kinds::ShiftedCyclicPart;
use memhier::util::table::TextTable;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    println!("== §3.2 pattern families and the classifier ==\n");
    let patterns: Vec<(&str, AccessPattern)> = vec![
        ("sequential", AccessPattern::Sequential { start: 0, len: 64 }),
        ("cyclic", AccessPattern::Cyclic { start: 0, cycle_length: 16, cycles: 8 }),
        (
            "shifted cyclic",
            AccessPattern::ShiftedCyclic {
                start: 0,
                cycle_length: 16,
                inter_cycle_shift: 4,
                skip_shift: 0,
                cycles: 8,
            },
        ),
        ("strided", AccessPattern::Strided { start: 0, stride: 4, len: 64 }),
        ("pseudo-random", AccessPattern::PseudoRandom { start: 0, range: 256, len: 128, seed: 7 }),
        (
            "parallel-shifted cyclic",
            AccessPattern::ParallelShiftedCyclic {
                parts: vec![
                    ShiftedCyclicPart { start: 0, cycle_length: 8, inter_cycle_shift: 2 },
                    ShiftedCyclicPart { start: 1000, cycle_length: 8, inter_cycle_shift: 2 },
                ],
                rounds: 8,
            },
        ),
    ];
    let mut t = TextTable::new(vec!["pattern", "accesses", "unique", "reuse", "classified_as", "mcu"]);
    for (name, p) in &patterns {
        let trace = p.addresses();
        let c = classify_trace(&trace);
        t.row(vec![
            name.to_string(),
            trace.len().to_string(),
            p.unique_addresses().to_string(),
            format!("{:.2}", p.reuse_factor()),
            format!("{c:?}").chars().take(48).collect(),
            if c.mcu_supported() { "yes" } else { "NO (§5.3)" }.to_string(),
        ]);
    }
    println!("{}", t.render());

    println!("\n== Fig-4-style waveform: write-over-read on a single-ported level ==\n");
    let cfg = HierarchyConfig::builder()
        .offchip(32, 24, 1.0)
        .level(32, 64, 1, 1) // single-ported L0: write wins the port
        .level(32, 16, 1, 2)
        .build()?;
    let mut h = Hierarchy::new(&cfg)?;
    h.load_program(&PatternProgram::cyclic(0, 8).with_outputs(64))?;
    h.attach_waveform();
    h.run()?;
    let wf = h.take_waveform().expect("attached");
    println!("{}", wf.to_ascii(0, 48));
    println!("(# = asserted; L1_read is the output port. Note the 3-cycle");
    println!(" input-buffer cadence on L0_write and the fill-then-stream");
    println!(" transition once the 8-word window is resident in L1.)");
    Ok(())
}
